"""Global-link traffic accounting and α-β performance model (paper Sec. 2.4, 5).

Counts, for any schedule from ``core.schedules``, the bytes crossing group
boundaries on grouped topologies (Dragonfly / Dragonfly+ / oversubscribed
fat-tree / TPU multi-pod) and hop-bytes on tori, plus a contention-aware
α-β time model used to reproduce the paper's win/loss tables and heatmaps.

All byte counts assume minimal inter-group routing, as the paper does
("the reductions we report should be interpreted as lower bounds").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .schedules import Msg, Sched, get_schedule


# ---------------------------------------------------------------------------
# Topologies
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GroupedTopo:
    """Two-tier network: fully-connected (fast) groups + sparse global links.

    Covers Dragonfly (LUMI), Dragonfly+ (Leonardo), 2:1-oversubscribed
    fat-tree subtrees (MareNostrum 5) and TPU multi-pod (ICI pods + DCN).
    """
    name: str
    group_size: int                  # nodes per group
    alpha_local: float = 1.0e-6      # s
    beta_local: float = 1.0 / 25e9   # s/B  (~200 Gb/s NIC)
    alpha_global: float = 2.0e-6
    beta_global: float = 1.0 / 25e9
    uplinks_per_group: int = 32      # concurrent crossing flows share these
    node_size: int = 1               # ranks per node (the innermost tier)

    def group_of(self, node: int) -> int:
        return node // self.group_size


#: presets mirroring the paper's four systems + the TPU target.
#: ``node_size`` = GPUs/chips per node: LUMI 4x MI250X (8 GCDs),
#: Leonardo/MN5 4x A100/H100, one TPU host = 4 chips — the innermost
#: tier ``repro.topology.tier_split`` derives hierarchies from.
LUMI = GroupedTopo("lumi_dragonfly", group_size=124, node_size=8)
LEONARDO = GroupedTopo("leonardo_dragonfly_plus", group_size=180, node_size=4)
MARENOSTRUM5 = GroupedTopo("mn5_fat_tree_2to1", group_size=160,
                           uplinks_per_group=80, node_size=4)
TPU_MULTIPOD = GroupedTopo(
    "tpu_multipod", group_size=256,
    alpha_local=1.0e-6, beta_local=1.0 / 50e9,     # ICI per-link
    alpha_global=10.0e-6, beta_global=1.0 / 25e9,  # DCN per pod-pair
    uplinks_per_group=8, node_size=4,
)


@dataclass(frozen=True)
class TorusTopo:
    """d-dimensional torus (Fugaku-like).  Cost ∝ hop-bytes."""
    name: str
    dims: Tuple[int, ...]
    alpha: float = 1.0e-6
    beta: float = 1.0 / 6.8e9  # 54.4 Gb/s TNI

    def coords(self, node: int) -> Tuple[int, ...]:
        c = []
        for d in reversed(self.dims):
            c.append(node % d)
            node //= d
        return tuple(reversed(c))

    def hops(self, a: int, b: int) -> int:
        ca, cb = self.coords(a), self.coords(b)
        h = 0
        for x, y, d in zip(ca, cb, self.dims):
            delta = abs(x - y)
            h += min(delta, d - delta)
        return h


# ---------------------------------------------------------------------------
# Byte accounting
# ---------------------------------------------------------------------------

def msg_bytes(m: Msg, p: int, vec_bytes: float) -> float:
    return m.nblocks(p) * vec_bytes / p


def total_bytes(sched: Sched, p: int, vec_bytes: float) -> float:
    return sum(msg_bytes(m, p, vec_bytes) for step in sched for m in step)


def global_bytes(
    sched: Sched,
    p: int,
    vec_bytes: float,
    topo: GroupedTopo,
    placement: Optional[Sequence[int]] = None,
) -> float:
    """Bytes crossing group boundaries.  ``placement[r]`` = node of rank r
    (defaults to the identity: rank == node, linear block placement)."""
    place = (lambda r: r) if placement is None else (lambda r: placement[r])
    out = 0.0
    for step in sched:
        for m in step:
            if topo.group_of(place(m.src)) != topo.group_of(place(m.dst)):
                out += msg_bytes(m, p, vec_bytes)
    return out


def hop_bytes(
    sched: Sched,
    p: int,
    vec_bytes: float,
    topo: TorusTopo,
    placement: Optional[Sequence[int]] = None,
) -> float:
    """Σ bytes·hops over all messages (torus link-load proxy)."""
    place = (lambda r: r) if placement is None else (lambda r: placement[r])
    out = 0.0
    for step in sched:
        for m in step:
            out += msg_bytes(m, p, vec_bytes) * topo.hops(place(m.src), place(m.dst))
    return out


def traffic_reduction(
    collective: str,
    algo_bine: str,
    algo_base: str,
    p: int,
    vec_bytes: float,
    topo: GroupedTopo,
    placement: Optional[Sequence[int]] = None,
    root: int = 0,
) -> float:
    """(base_global - bine_global) / base_global, as in Tables 3-5."""
    gb = global_bytes(get_schedule(collective, algo_bine, p, root), p, vec_bytes,
                      topo, placement)
    ga = global_bytes(get_schedule(collective, algo_base, p, root), p, vec_bytes,
                      topo, placement)
    if ga == 0:
        return 0.0
    return (ga - gb) / ga


# ---------------------------------------------------------------------------
# Closed-form byte counts for composed (hierarchical) schedules
# ---------------------------------------------------------------------------

def _tier_wire_blocks(collective: str, algo: str, pt: int) -> int:
    """Σ blocks on the wire across the flat tier schedule at radix ``pt``
    (the same builder ``compose`` lifts, adapters included)."""
    sched = get_schedule(collective, algo, pt)
    return sum(m.nblocks(pt) for step in sched for m in step)


def compose_phase_bytes(
    collective: str,
    tiers: Sequence[int],
    vec_bytes: float,
    algo: str = "bine",
) -> Tuple[float, ...]:
    """Per-phase wire bytes of ``compose(collective, tiers, algo)``,
    indexed by tier (innermost first, i.e. digit order — not execution
    order; allgather runs the same phases mirrored, allreduce both ways).

    Phase j runs the flat radix-``tiers[j]`` schedule inside each of the
    p/tiers[j] subgroups, and every virtual block lifts to
    ``E_j = prod(tiers[j+1:])`` real blocks of ``vec_bytes / p``, so

        bytes_j = (p / p_j) · wire_blocks(p_j) · E_j · vec_bytes / p.

    Exact for any tier radix: non-pow2 tiers are priced through the same
    fold / 3-2-elimination adapters ``compose`` lifts.
    """
    tiers = tuple(int(t) for t in tiers)
    p = int(np.prod(tiers))
    out = []
    for j, pt in enumerate(tiers):
        if pt == 1:
            out.append(0.0)
            continue
        e_j = int(np.prod(tiers[j + 1:], dtype=np.int64))
        if collective == "allreduce":
            wire = (_tier_wire_blocks("reduce_scatter", algo, pt)
                    + _tier_wire_blocks("allgather", algo, pt))
        else:
            wire = _tier_wire_blocks(collective, algo, pt)
        out.append((p // pt) * wire * e_j * vec_bytes / p)
    return tuple(out)


def compose_global_bytes(
    collective: str,
    tiers: Sequence[int],
    vec_bytes: float,
    per_group: int,
    algo: str = "bine",
) -> float:
    """Bytes of ``compose(collective, tiers, algo)`` crossing group
    boundaries under tier-aligned placement (``per_group`` consecutive
    ranks per group, as built by ``tuner.trace.spread_placement``).

    ``per_group`` must equal ``prod(tiers[:k])`` for some k.  Then phase
    j < k stays inside one group (its subgroup spans prod(tiers[:j+1])
    ≤ per_group consecutive ranks starting at a multiple of it) and
    phase j ≥ k is entirely crossing (peers differ by a nonzero multiple
    of the digit stride, itself a multiple of per_group), so the global
    traffic is exactly the sum of the outer phases — this is the closed
    form behind the hierarchy's locality win: the inner (p_0−1)·E_0 term,
    the bulk of the bytes, never leaves the group.
    """
    tiers = tuple(int(t) for t in tiers)
    prefix, k = 1, None
    for i in range(len(tiers) + 1):
        if prefix == per_group:
            k = i
            break
        if i < len(tiers):
            prefix *= tiers[i]
    if k is None:
        raise ValueError(
            f"per_group={per_group} is not a prefix product of tiers "
            f"{tiers}; tier-aligned placement needs prod(tiers[:k])")
    return float(sum(
        compose_phase_bytes(collective, tiers, vec_bytes, algo)[k:]))


# ---------------------------------------------------------------------------
# α-β time model with global-link contention
# ---------------------------------------------------------------------------

def sched_time(
    sched: Sched,
    p: int,
    vec_bytes: float,
    topo: GroupedTopo,
    placement: Optional[Sequence[int]] = None,
    segment_bytes: Optional[float] = None,
) -> float:
    """Bulk-synchronous estimate: per step, every flow proceeds in parallel;
    flows crossing a group's uplinks share them; the step ends when the
    slowest flow ends.  ``segment_bytes`` models pipelined segmentation by
    amortizing α over ceil(msg/segment) chunks (paper Sec. 5.2.2).
    """
    place = (lambda r: r) if placement is None else (lambda r: placement[r])
    t = 0.0
    for step in sched:
        crossing: Dict[int, int] = {}
        flows: List[Tuple[float, bool, int]] = []
        for m in step:
            gs, gd = topo.group_of(place(m.src)), topo.group_of(place(m.dst))
            b = msg_bytes(m, p, vec_bytes)
            cross = gs != gd
            if cross:
                crossing[gs] = crossing.get(gs, 0) + 1
            flows.append((b, cross, gs))
        worst = 0.0
        for b, cross, gs in flows:
            if cross:
                share = max(1.0, crossing[gs] / topo.uplinks_per_group)
                tm = topo.alpha_global + b * topo.beta_global * share
            else:
                tm = topo.alpha_local + b * topo.beta_local
            worst = max(worst, tm)
        t += worst
    return t


def torus_time(
    sched: Sched,
    p: int,
    vec_bytes: float,
    topo: TorusTopo,
    placement: Optional[Sequence[int]] = None,
) -> float:
    """Per step: slowest flow, charged α·hops + bytes·β·mean-link-contention."""
    place = (lambda r: r) if placement is None else (lambda r: placement[r])
    n_links = len(topo.dims) * 2 * int(np.prod(topo.dims))
    t = 0.0
    for step in sched:
        hb = 0.0
        worst = 0.0
        msgs = []
        for m in step:
            h = topo.hops(place(m.src), place(m.dst))
            b = msg_bytes(m, p, vec_bytes)
            hb += h * b
            msgs.append((h, b))
        mean_load = hb / max(n_links, 1)
        for h, b in msgs:
            contention = max(1.0, hb / (b * max(h, 1)) / max(n_links, 1) * len(msgs))
            worst = max(worst, topo.alpha * max(h, 1) + b * topo.beta
                        + mean_load * topo.beta)
        t += worst
    return t


# ---------------------------------------------------------------------------
# Allocation sampling (Fig. 5 reproduction)
# ---------------------------------------------------------------------------

def sample_allocation(
    rng: np.random.RandomState,
    n_nodes: int,
    topo: GroupedTopo,
    n_groups_total: int = 24,
) -> List[int]:
    """Sample a scheduler-like allocation: nodes spread over a random subset
    of groups with uneven per-group counts, then sorted (the paper's
    'sort ranks by hostname' block remapping).  Returns node ids per rank.
    """
    g = topo.group_size
    max_groups = min(n_groups_total, max(1, int(np.ceil(n_nodes / g))))
    # jobs usually spread across more groups than strictly needed
    spread = rng.randint(max_groups, min(n_groups_total, max_groups * 4) + 1)
    groups = rng.choice(n_groups_total, size=spread, replace=False)
    # uneven distribution of node counts over the chosen groups
    weights = rng.dirichlet(np.ones(spread) * 1.5)
    counts = np.maximum(0, np.round(weights * n_nodes).astype(int))
    counts = np.minimum(counts, g)
    # fix rounding to hit exactly n_nodes
    while counts.sum() < n_nodes:
        i = rng.randint(spread)
        if counts[i] < g:
            counts[i] += 1
    while counts.sum() > n_nodes:
        i = rng.randint(spread)
        if counts[i] > 0:
            counts[i] -= 1
    nodes: List[int] = []
    for grp, cnt in zip(groups, counts):
        slots = rng.choice(g, size=cnt, replace=False)
        nodes.extend(int(grp) * g + int(s) for s in slots)
    nodes.sort()
    return nodes


def allocation_reduction_distribution(
    collective: str,
    algo_bine: str,
    algo_base: str,
    n_nodes: int,
    topo: GroupedTopo,
    n_jobs: int = 50,
    vec_bytes: float = 1 << 20,
    seed: int = 0,
) -> np.ndarray:
    """Traffic-reduction distribution across sampled allocations (Fig. 5)."""
    rng = np.random.RandomState(seed)
    sb = get_schedule(collective, algo_bine, n_nodes)
    sa = get_schedule(collective, algo_base, n_nodes)
    out = []
    for _ in range(n_jobs):
        placement = sample_allocation(rng, n_nodes, topo)
        gb = global_bytes(sb, n_nodes, vec_bytes, topo, placement)
        ga = global_bytes(sa, n_nodes, vec_bytes, topo, placement)
        out.append(0.0 if ga == 0 else (ga - gb) / ga)
    return np.array(out)
