"""Negabinary rank arithmetic — the algebra behind Bine trees (paper Sec. 2.3.1, 3.2.1).

Every rank of a p-rank collective (p = 2**s) gets an s-bit *negabinary*
(base -2) label.  Ranks in ``[0, m]`` (right of the root on the rank circle)
use the negabinary representation of ``r``; ranks in ``(m, p)`` (left of the
root) use the representation of ``r - p``, where ``m`` is the largest
non-negative integer representable in s negabinary bits (``0101...01`` —
ones in the even positions).

All functions are plain-int and numpy-vectorized; no JAX dependency — this
module is the pure algorithm layer shared by the simulator, the traffic
model, and the JAX collectives (which bake its outputs in as static
constants).
"""

from __future__ import annotations

import numpy as np

# A wide alternating 1010...10 mask.  Schroeppel's trick converts two's
# complement to negabinary: nb = (n + MASK) ^ MASK, and back:
# n = (nb ^ MASK) - MASK.  64 alternating bits cover any |n| < 2**62.
_MASK = 0xAAAAAAAAAAAAAAAA


def int_to_neg(n: int) -> int:
    """Negabinary bit pattern (as a python int) of integer ``n`` (may be <0)."""
    return (int(n) + _MASK) ^ _MASK


def neg_to_int(nb: int) -> int:
    """Signed integer value of negabinary bit pattern ``nb``."""
    return (int(nb) ^ _MASK) - _MASK


def log2_int(p: int) -> int:
    s = int(p).bit_length() - 1
    if (1 << s) != p:
        raise ValueError(f"p={p} is not a power of two")
    return s


def max_positive(s: int) -> int:
    """Largest value representable in ``s`` negabinary bits: 0101...01₋₂.

    Ones in even bit positions only (even powers of -2 are positive).
    E.g. s=6 → 010101₋₂ = 16+4+1 = 21;  s=3 → 101₋₂ = 5.
    """
    return neg_to_int(sum(1 << j for j in range(0, s, 2)))


def rank2nb(r: int, p: int) -> int:
    """Rank identifier → s-bit negabinary label (paper Sec. 2.3.1)."""
    s = log2_int(p)
    m = max_positive(s)
    r = int(r) % p
    nb = int_to_neg(r) if r <= m else int_to_neg(r - p)
    assert nb < (1 << s), (r, p, nb)
    return nb


def nb2rank(nb: int, p: int) -> int:
    """s-bit negabinary label → rank identifier in [0, p)."""
    return neg_to_int(nb) % p


def trailing_run(nb: int, s: int) -> int:
    """Length u of the run of equal bits starting at the LSB of an s-bit label.

    E.g. (paper Sec. 2.3.2, 16 ranks): u=3 for 1000, u=2 for 1011.
    """
    b0 = nb & 1
    u = 0
    for j in range(s):
        if (nb >> j) & 1 == b0:
            u += 1
        else:
            break
    return u


def ones(k: int) -> int:
    """k least-significant bits set: the XOR masks 1, 11, 111, ... of Eq. 1."""
    return (1 << k) - 1


# ---------------------------------------------------------------------------
# Distance-doubling labels (paper Sec. 3.2.1)
# ---------------------------------------------------------------------------

def h_label(r: int, p: int) -> int:
    """h(r,p): rank2nb(p-r) for even ranks, rank2nb(r) for odd ranks."""
    r = int(r) % p
    return rank2nb((p - r) % p, p) if r % 2 == 0 else rank2nb(r, p)


def v_label(r: int, p: int) -> int:
    """v(r,p) = h(r,p) XOR (h(r,p) >> 1) — the distance-doubling tree label."""
    h = h_label(r, p)
    return h ^ (h >> 1)


def v_table(p: int) -> np.ndarray:
    """v(r) for every rank, as an int64 array of length p."""
    return np.array([v_label(r, p) for r in range(p)], dtype=np.int64)


def v_inverse(p: int) -> np.ndarray:
    """inv[v] = r such that v_label(r) == v.  Raises if v is not a bijection."""
    vt = v_table(p)
    inv = np.full(p, -1, dtype=np.int64)
    inv[vt] = np.arange(p, dtype=np.int64)
    if (inv < 0).any():
        raise AssertionError(f"v labels are not a bijection for p={p}")
    return inv


def reverse_bits(x: int, s: int) -> int:
    out = 0
    for j in range(s):
        out |= ((x >> j) & 1) << (s - 1 - j)
    return out


# ---------------------------------------------------------------------------
# Modulo distance (paper Sec. 2.2) and butterfly deltas (Eq. 3/4)
# ---------------------------------------------------------------------------

def mod_distance(r: int, q: int, p: int) -> int:
    """d(r,q) = min((r-q) mod p, (q-r) mod p)."""
    a = (r - q) % p
    return min(a, p - a)


def bine_delta(k: int) -> int:
    """|Σ_{j<k} (-2)^j| signed form: (1 - (-2)**k) / 3  (Eq. 3 numerator).

    This is the value of the negabinary number 111...1 (k ones):
    k=1 → 1, k=2 → -1, k=3 → 3, k=4 → -5, k=5 → 11, ...
    """
    return (1 - (-2) ** k) // 3
