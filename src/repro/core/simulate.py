"""Numpy execution of collective schedules — the correctness oracle.

Executes a schedule from ``core.schedules`` on real per-rank numpy buffers
and checks the result against the mathematical definition of the
collective.  Used by unit/property tests and (indirectly) to certify the
static tables baked into the JAX shard_map backends.

Block convention: the collective vector has p blocks; ``data[r]`` is rank
r's input contribution.  Values are float64 arrays of shape ``(p, blk)``
(block-major) so reductions are exact for small integers.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from .schedules import BLOCK_ALL, Sched, get_schedule, step_kinds


def _exec_block_steps(held: List[Dict[int, np.ndarray]], sched: Sched,
                      kinds) -> None:
    """Kind-driven block engine (kind semantics: schedules docstring).

    reduce: src relinquishes the blocks; dst must still hold them and
            accumulates.  move: src relinquishes; dst installs (and must
            not already hold them).  copy: src keeps; dst installs,
            values must agree on overlap.
    """
    for step, kind in zip(sched, kinds):
        moves = []
        for m in step:
            payload = {}
            for b in m.blocks:
                assert b in held[m.src], (
                    f"{kind}: rank {m.src} sends block {b} it does not hold")
                payload[b] = held[m.src][b]
            moves.append((m.src, m.dst, payload))
        if kind in ("reduce", "move"):
            for src, _, payload in moves:
                for b in payload:
                    del held[src][b]
        for _, dst, payload in moves:
            for b, v in payload.items():
                if kind == "reduce":
                    assert b in held[dst], (
                        f"reduce: rank {dst} no longer accumulates block {b}")
                    held[dst][b] = held[dst][b] + v
                elif kind == "move":
                    assert b not in held[dst], (
                        f"move: rank {dst} already holds block {b}")
                    held[dst][b] = v
                else:  # copy
                    if b in held[dst]:
                        assert (held[dst][b] == v).all()
                    held[dst][b] = v


def _composite_kinds(sched: Sched, first: str, second: str):
    """Kinds for a schedule: the IR's own tags, or the legacy symmetric
    midpoint split for plain step lists."""
    kinds = getattr(sched, "kinds", None)
    if kinds is not None:
        return tuple(kinds)
    assert len(sched) % 2 == 0
    half = len(sched) // 2
    return (first,) * half + (second,) * half


def _inputs(p: int, blk: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    return rng.randint(-8, 8, size=(p, p, blk)).astype(np.float64)


def run_broadcast(sched: Sched, p: int, root: int, blk: int = 4) -> None:
    x = _inputs(p, blk)[root]
    have: List[np.ndarray | None] = [None] * p
    have[root] = x
    for step in sched:
        incoming: Dict[int, np.ndarray] = {}
        for m in step:
            assert m.blocks == (BLOCK_ALL,)
            assert have[m.src] is not None, f"rank {m.src} sends before receiving"
            assert m.dst not in incoming, f"rank {m.dst} receives twice in a step"
            incoming[m.dst] = have[m.src]
        for dst, val in incoming.items():
            assert have[dst] is None, f"rank {dst} receives but already has data"
            have[dst] = val
    for r in range(p):
        assert have[r] is not None and (have[r] == x).all(), f"bcast wrong at {r}"


def run_reduce(sched: Sched, p: int, root: int, blk: int = 4) -> None:
    data = _inputs(p, blk)
    acc = [data[r].copy() for r in range(p)]
    done = [False] * p
    for step in sched:
        incoming: Dict[int, List[np.ndarray]] = {}
        for m in step:
            assert not done[m.src], f"rank {m.src} sends twice"
            incoming.setdefault(m.dst, []).append(acc[m.src])
            done[m.src] = True
        for dst, vals in incoming.items():
            for v in vals:
                acc[dst] = acc[dst] + v
    expect = data.sum(axis=0)
    assert (acc[root] == expect).all(), "reduce result wrong at root"


def run_gather(sched: Sched, p: int, root: int, blk: int = 4) -> None:
    data = _inputs(p, blk)
    held: List[Dict[int, np.ndarray]] = [{r: data[r][r]} for r in range(p)]
    for step in sched:
        moves = []
        for m in step:
            assert set(m.blocks) == set(held[m.src]), (
                f"gather: rank {m.src} sends {m.blocks} but holds "
                f"{sorted(held[m.src])}")
            moves.append((m.src, m.dst, {b: held[m.src][b] for b in m.blocks}))
        for src, dst, payload in moves:
            held[src] = {}
            for b, v in payload.items():
                assert b not in held[dst]
                held[dst][b] = v
    assert sorted(held[root]) == list(range(p))
    for b in range(p):
        assert (held[root][b] == data[b][b]).all()


def run_scatter(sched: Sched, p: int, root: int, blk: int = 4) -> None:
    data = _inputs(p, blk)[root]  # root holds p blocks
    held: List[Dict[int, np.ndarray]] = [{} for _ in range(p)]
    held[root] = {b: data[b] for b in range(p)}
    for step in sched:
        moves = []
        for m in step:
            for b in m.blocks:
                assert b in held[m.src], (
                    f"scatter: rank {m.src} sends block {b} it does not hold")
            moves.append((m.src, m.dst, {b: held[m.src][b] for b in m.blocks}))
        for src, dst, payload in moves:
            for b in payload:
                del held[src][b]
            held[dst].update(payload)
    for r in range(p):
        assert set(held[r]) == {r}, f"scatter: rank {r} holds {sorted(held[r])}"
        assert (held[r][r] == data[r]).all()


def run_reduce_scatter(sched: Sched, p: int, blk: int = 4) -> None:
    data = _inputs(p, blk)
    held: List[Dict[int, np.ndarray]] = [
        {b: data[r][b].copy() for b in range(p)} for r in range(p)
    ]
    _exec_block_steps(held, sched, step_kinds(sched, "reduce"))
    expect = data.sum(axis=0)
    for r in range(p):
        assert set(held[r]) == {r}, f"RS: rank {r} ends with {sorted(held[r])}"
        assert (held[r][r] == expect[r]).all(), f"RS: wrong sum at rank {r}"


def run_allgather(sched: Sched, p: int, blk: int = 4) -> None:
    data = _inputs(p, blk)
    held: List[Dict[int, np.ndarray]] = [{r: data[r][r]} for r in range(p)]
    for step in sched:
        moves = []
        for m in step:
            payload = {b: held[m.src][b] for b in m.blocks}
            assert len(payload) == len(m.blocks)
            moves.append((m.dst, payload))
        for dst, payload in moves:
            for b, v in payload.items():
                if b in held[dst]:
                    assert (held[dst][b] == v).all()
                held[dst][b] = v
    for r in range(p):
        assert sorted(held[r]) == list(range(p))
        for b in range(p):
            assert (held[r][b] == data[b][b]).all()


def run_allreduce(sched: Sched, p: int, blk: int = 4) -> None:
    """Handles both small (full-vector recursive doubling) and large (RS+AG).

    Step kinds drive the buffer semantics: "reduce" steps relinquish at
    the sender and accumulate at the receiver, "copy"/"move" steps install
    completed sums.  Plain step lists fall back to the legacy symmetric
    midpoint split (first half RS, second half AG).
    """
    data = _inputs(p, blk)
    expect = data.sum(axis=0)
    # full-vector schedule? (recursive-doubling exchanges + adapter steps)
    if all(m.blocks == (BLOCK_ALL,) for step in sched for m in step):
        acc = [data[r].copy() for r in range(p)]
        for step, kind in zip(sched, step_kinds(sched, "reduce")):
            snap = [a.copy() for a in acc]
            for m in step:
                if kind == "copy":
                    acc[m.dst] = snap[m.src].copy()
                else:
                    acc[m.dst] = acc[m.dst] + snap[m.src]
        for r in range(p):
            assert (acc[r] == expect).all(), f"allreduce wrong at rank {r}"
        return

    held: List[Dict[int, np.ndarray]] = [
        {b: data[r][b].copy() for b in range(p)} for r in range(p)
    ]
    _exec_block_steps(held, sched, _composite_kinds(sched, "reduce", "copy"))
    for r in range(p):
        assert sorted(held[r]) == list(range(p)), f"rank {r}: {sorted(held[r])}"
        for b in range(p):
            assert (held[r][b] == expect[b]).all(), f"allreduce wrong {r},{b}"


def run_broadcast_large(sched: Sched, p: int, root: int, blk: int = 4) -> None:
    """scatter + allgather composite: root's p blocks reach every rank."""
    data = _inputs(p, blk)[root]
    held: List[Dict[int, np.ndarray]] = [{} for _ in range(p)]
    held[root] = {b: data[b] for b in range(p)}
    _exec_block_steps(held, sched, _composite_kinds(sched, "move", "copy"))
    for r in range(p):
        assert sorted(held[r]) == list(range(p)), f"rank {r}: {sorted(held[r])}"
        for b in range(p):
            assert (held[r][b] == data[b]).all()


def run_reduce_large(sched: Sched, p: int, root: int, blk: int = 4) -> None:
    """reduce-scatter + gather composite: root ends with the full sum."""
    data = _inputs(p, blk)
    expect = data.sum(axis=0)
    held: List[Dict[int, np.ndarray]] = [
        {b: data[r][b].copy() for b in range(p)} for r in range(p)
    ]
    _exec_block_steps(held, sched, _composite_kinds(sched, "reduce", "move"))
    assert sorted(held[root]) == list(range(p))
    for b in range(p):
        assert (held[root][b] == expect[b]).all(), f"reduce_large wrong blk {b}"


def run_alltoall(sched: Sched, p: int, blk: int = 4) -> None:
    data = _inputs(p, blk)  # data[r][d] = block rank r sends to rank d
    held: List[Dict[int, np.ndarray]] = [
        {d * p + r: data[r][d] for d in range(p)} for r in range(p)
    ]
    for step in sched:
        moves = []
        for m in step:
            payload = {}
            for key in m.blocks:
                assert key in held[m.src], (
                    f"a2a: rank {m.src} sends (d={key//p},o={key%p}) not held")
                payload[key] = held[m.src][key]
            moves.append((m.src, m.dst, payload))
        for src, dst, payload in moves:
            for key in payload:
                del held[src][key]
        for src, dst, payload in moves:
            for key, v in payload.items():
                held[dst][key] = v
    for r in range(p):
        keys = sorted(held[r])
        assert keys == [r * p + o for o in range(p)], f"a2a: rank {r} {keys}"
        for o in range(p):
            assert (held[r][r * p + o] == data[o][r]).all()


def check(collective: str, algo: str, p: int, root: int = 0, blk: int = 4) -> None:
    """Build the schedule and verify it end-to-end.  Raises on any violation."""
    sched = get_schedule(collective, algo, p, root)
    large = algo.endswith("large")
    if collective == "broadcast":
        (run_broadcast_large if large else run_broadcast)(sched, p, root, blk)
    elif collective == "reduce":
        (run_reduce_large if large else run_reduce)(sched, p, root, blk)
    elif collective == "gather":
        run_gather(sched, p, root, blk)
    elif collective == "scatter":
        run_scatter(sched, p, root, blk)
    elif collective == "reduce_scatter":
        run_reduce_scatter(sched, p, blk)
    elif collective == "allgather":
        run_allgather(sched, p, blk)
    elif collective == "allreduce":
        run_allreduce(sched, p, blk)
    elif collective == "alltoall":
        run_alltoall(sched, p, blk)
    else:
        raise KeyError(collective)
