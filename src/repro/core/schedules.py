"""Per-collective communication schedules (paper Sec. 4) for every algorithm.

A schedule is a list of steps; each step is a list of ``Msg`` records
``(src, dst, blocks)`` where ``blocks`` is the ordered tuple of vector-block
indices carried by the message (block = 1/p of the vector for most
collectives; for broadcast/reduce "small" the whole vector is block 0 and
counts as p pseudo-blocks for byte accounting — see ``Msg.nblocks``).

Algorithms:
  trees       : bine_dh | bine_dd | binomial_dh | binomial_dd
  butterflies : bine_dh | bine_dd | recdoub_dh | recdoub_dd
  linear      : ring, bruck (alltoall baseline)

These schedules are consumed by
  * core.simulate   — numpy execution + oracle checks,
  * core.traffic    — per-link / global-link byte counting,
  * collectives.shmap — baked in as static ppermute step tables.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from . import butterflies as bf
from . import trees as tr
from .negabinary import log2_int

BLOCK_ALL = -1  # sentinel: message carries the full vector


@dataclass(frozen=True)
class Msg:
    src: int
    dst: int
    blocks: Tuple[int, ...]  # ordered block ids; (BLOCK_ALL,) = whole vector

    def nblocks(self, p: int) -> int:
        if self.blocks == (BLOCK_ALL,):
            return p
        return len(self.blocks)


Step = List[Msg]
Sched = List[Step]


# ---------------------------------------------------------------------------
# Broadcast / Reduce (small vectors): plain trees (paper Sec. 4.5)
# ---------------------------------------------------------------------------

def broadcast_sched(algo: str, p: int, root: int = 0) -> Sched:
    tree = tr.rotate_schedule(tr.TREES[algo](p), root, p)
    return [[Msg(a, b, (BLOCK_ALL,)) for a, b in step] for step in tree]


def reduce_sched(algo: str, p: int, root: int = 0) -> Sched:
    """Reduce = time-reversed broadcast; each edge flows child -> parent."""
    tree = tr.rotate_schedule(tr.TREES[algo](p), root, p)
    return [[Msg(b, a, (BLOCK_ALL,)) for a, b in step] for step in reversed(tree)]


# ---------------------------------------------------------------------------
# Gather / Scatter: trees with per-subtree block sets (paper Sec. 4.1/4.2)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _subtrees(algo: str, p: int) -> Tuple[Tuple[int, ...], ...]:
    sub = tr.subtree_blocks(tr.TREES[algo](p), p)
    return tuple(tuple(sorted(x)) for x in sub)


def gather_sched(algo: str, p: int, root: int = 0) -> Sched:
    """Each rank forwards its whole accumulated subtree to its parent.

    Accumulated sets are replayed exactly (order preserved mod-p contiguous
    for bine_dh / binomial trees, per paper Sec. 4.1).
    """
    tree = tr.TREES[algo](p)
    held: List[List[int]] = [[r] for r in range(p)]
    sched: Sched = []
    for step in reversed(tree):
        msgs: Step = []
        for parent, child in step:
            msgs.append(Msg(child, parent, tuple(held[child])))
            held[parent] = _merge_mod_contig(held[parent], held[child], p)
        sched.append(msgs)
    assert sorted(held[0]) == list(range(p))
    return _rotate_msgs(sched, root, p)


def scatter_sched(algo: str, p: int, root: int = 0) -> Sched:
    """Scatter = time-reversed gather: parent sends child's subtree blocks."""
    g = gather_sched(algo, p, 0)
    sched = [[Msg(m.dst, m.src, m.blocks) for m in step] for step in reversed(g)]
    return _rotate_msgs(sched, root, p) if root else sched


def _merge_mod_contig(a: List[int], b: List[int], p: int) -> List[int]:
    """Merge two block lists, keeping mod-p contiguous order when possible."""
    if (a[-1] + 1) % p == b[0] % p:
        return a + b
    if (b[-1] + 1) % p == a[0] % p:
        return b + a
    return a + b  # non-contiguous (bine_dd subtrees) — order by arrival


def _rotate_msgs(sched: Sched, root: int, p: int) -> Sched:
    if root % p == 0:
        return sched
    return [
        [
            Msg((m.src + root) % p, (m.dst + root) % p,
                tuple((blk + root) % p for blk in m.blocks)
                if m.blocks != (BLOCK_ALL,) else m.blocks)
            for m in step
        ]
        for step in sched
    ]


# ---------------------------------------------------------------------------
# Reduce-scatter / Allgather: vector-halving/-doubling butterflies (Sec. 4.3)
# ---------------------------------------------------------------------------

def reduce_scatter_sched(algo: str, p: int) -> Sched:
    """Vector-halving butterfly RS.  At step i, r sends the partial sums of
    every block in its partner's next-level cone.

    Result: rank r holds the full sum of block ``final_block(algo)[r]``
    (identity block only after the Sec. 4.3.1 contiguity permutation).
    """
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    cs = bf.cones(algo, p)
    sched: Sched = []
    for i in range(s):
        msgs: Step = []
        for r in range(p):
            q = int(tab[i, r])
            msgs.append(Msg(r, q, tuple(sorted(cs[i + 1][q]))))
        sched.append(msgs)
    return sched


def allgather_sched(algo: str, p: int) -> Sched:
    """Vector-doubling butterfly AG: r sends every block it has accumulated."""
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    held: List[List[int]] = [[r] for r in range(p)]
    sched: Sched = []
    for i in range(s):
        msgs: Step = []
        snapshot = [list(x) for x in held]
        for r in range(p):
            q = int(tab[i, r])
            assert not set(snapshot[r]) & set(snapshot[q]), (
                algo, p, i, r, "allgather exchange would duplicate blocks")
            msgs.append(Msg(r, q, tuple(snapshot[r])))
        for r in range(p):
            held[r] = snapshot[r] + snapshot[int(tab[i, r])]
        sched.append(msgs)
    for r in range(p):
        assert sorted(held[r]) == list(range(p))
    return sched


def allreduce_large_sched(algo_rs: str, algo_ag: str, p: int) -> Sched:
    """Large-vector allreduce = RS (distance-doubling) + AG (distance-halving).

    Block bookkeeping: the AG must redistribute exactly the blocks the RS
    left behind, so its per-step block sets are the RS cones replayed
    forward.  (paper Sec. 4.4)
    """
    # Block-exact view: the RS leaves rank r holding the full sum of block r
    # (message *contents* may be non-contiguous in buffer space — that is the
    # Sec. 4.3.1 permutation's job, handled positionally in collectives.shmap).
    return reduce_scatter_sched(algo_rs, p) + allgather_sched(algo_ag, p)


def allreduce_small_sched(algo: str, p: int) -> Sched:
    """Small-vector allreduce: recursive doubling, full vector each step."""
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    return [
        [Msg(r, int(tab[i, r]), (BLOCK_ALL,)) for r in range(p)]
        for i in range(s)
    ]


# ---------------------------------------------------------------------------
# Alltoall: butterfly-routed (Bruck-like, paper Sec. 4.4)
# ---------------------------------------------------------------------------

def alltoall_sched(algo: str, p: int) -> Sched:
    """Each rank starts with p blocks (one per destination).  At step i it
    forwards to its partner every block whose *destination* lies in the
    partner's next-level cone.  Every block reaches its destination after
    s steps; each step moves exactly p/2 blocks per rank (n/2 bytes).
    """
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    cs = bf.cones(algo, p)
    # held[r] = list of (dest, origin) pairs currently buffered at r
    held: List[List[Tuple[int, int]]] = [
        [(d, r) for d in range(p)] for r in range(p)
    ]
    sched: Sched = []
    for i in range(s):
        msgs: Step = []
        moved: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        kept: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        for r in range(p):
            q = int(tab[i, r])
            qcone = cs[i + 1][q]
            send = [x for x in held[r] if x[0] in qcone]
            keep = [x for x in held[r] if x[0] not in qcone]
            # encode (dest, origin) pairs as dest*p + origin (uniform n/p size)
            msgs.append(Msg(r, q, tuple(d * p + o for d, o in send)))
            moved[q].extend(send)
            kept[r] = keep
        for r in range(p):
            held[r] = kept[r] + moved[r]
        sched.append(msgs)
    for r in range(p):
        assert sorted(d for d, _ in held[r]) == [r] * p
        assert sorted(o for _, o in held[r]) == list(range(p))
    return sched


def bruck_alltoall_sched(p: int) -> Sched:
    """Classical Bruck alltoall baseline: step i sends, to rank r - 2**i,
    every block whose relative destination distance has bit i set."""
    s = log2_int(p)
    held: List[List[Tuple[int, int]]] = [
        [(d, r) for d in range(p)] for r in range(p)
    ]
    sched: Sched = []
    for i in range(s):
        msgs: Step = []
        moved: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        kept: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        for r in range(p):
            q = (r - (1 << i)) % p
            send = [x for x in held[r] if ((x[0] - r) % p) >> i & 1]
            keep = [x for x in held[r] if not ((x[0] - r) % p) >> i & 1]
            msgs.append(Msg(r, q, tuple(d * p + o for d, o in send)))
            moved[q].extend(send)
            kept[r] = keep
        for r in range(p):
            held[r] = kept[r] + moved[r]
        sched.append(msgs)
    for r in range(p):
        assert sorted(d for d, _ in held[r]) == [r] * p
    return sched


# ---------------------------------------------------------------------------
# Ring baselines
# ---------------------------------------------------------------------------

def ring_reduce_scatter_sched(p: int) -> Sched:
    """p-1 steps; step t: rank r sends partial block (r-t-1) mod p to r+1.

    Block b hops b+1 → b+2 → … → b, accumulating every contribution, so
    rank r ends holding the full sum of its own block r.
    """
    sched: Sched = []
    for t in range(p - 1):
        sched.append([Msg(r, (r + 1) % p, ((r - t - 1) % p,)) for r in range(p)])
    return sched


def ring_allgather_sched(p: int) -> Sched:
    sched: Sched = []
    for t in range(p - 1):
        sched.append([Msg(r, (r + 1) % p, ((r - t) % p,)) for r in range(p)])
    return sched


def ring_allreduce_sched(p: int) -> Sched:
    """Ring RS + ring AG (2(p-1) steps)."""
    return ring_reduce_scatter_sched(p) + ring_allgather_sched(p)


# ---------------------------------------------------------------------------
# Composite large-vector bcast / reduce (paper Sec. 4.5)
# ---------------------------------------------------------------------------

def broadcast_large_sched(family: str, p: int, root: int = 0) -> Sched:
    """scatter (distance-doubling tree) + allgather (distance-halving bfly)."""
    if family == "bine":
        sc = scatter_sched("bine_dd", p, root)
        ag = allgather_sched("bine_dh", p)
    else:
        sc = scatter_sched("binomial_dh", p, root)   # MPICH-style
        ag = allgather_sched("recdoub_dd", p)
    return sc + ag


def reduce_large_sched(family: str, p: int, root: int = 0) -> Sched:
    """reduce-scatter (distance-doubling bfly) + gather (dist-halving tree)."""
    if family == "bine":
        rs = reduce_scatter_sched("bine_dd", p)
        ga = gather_sched("bine_dh", p, root)
    else:
        rs = reduce_scatter_sched("recdoub_dd", p)
        ga = gather_sched("binomial_dh", p, root)
    return rs + ga


# ---------------------------------------------------------------------------
# Registry: collective -> {algorithm-name -> schedule builder}
# ---------------------------------------------------------------------------

#: collective -> algo -> builder(p, root).  The module-level registry lets
#: tests enumerate every (collective, algo) pair (``list_algos``) so the
#: conformance matrix covers pairs added later automatically.
_REGISTRY: Dict[str, Dict[str, Any]] = {
    "broadcast": {
        "bine": lambda p, root: broadcast_sched("bine_dh", p, root),
        "binomial_dh": lambda p, root: broadcast_sched("binomial_dh", p, root),
        "binomial_dd": lambda p, root: broadcast_sched("binomial_dd", p, root),
        "bine_large": lambda p, root: broadcast_large_sched("bine", p, root),
        "binomial_large": lambda p, root: broadcast_large_sched("binomial", p, root),
    },
    "reduce": {
        "bine": lambda p, root: reduce_sched("bine_dh", p, root),
        "binomial_dh": lambda p, root: reduce_sched("binomial_dh", p, root),
        "binomial_dd": lambda p, root: reduce_sched("binomial_dd", p, root),
        "bine_large": lambda p, root: reduce_large_sched("bine", p, root),
        "binomial_large": lambda p, root: reduce_large_sched("binomial", p, root),
    },
    "gather": {
        "bine": lambda p, root: gather_sched("bine_dh", p, root),
        "binomial": lambda p, root: gather_sched("binomial_dh", p, root),
    },
    "scatter": {
        # standalone scatter reverses the dh gather (Sec. 4.2); the
        # dd variant exists for the composite large-vector broadcast
        "bine": lambda p, root: scatter_sched("bine_dh", p, root),
        "bine_dd": lambda p, root: scatter_sched("bine_dd", p, root),
        "binomial": lambda p, root: scatter_sched("binomial_dh", p, root),
    },
    "reduce_scatter": {
        "bine": lambda p, root: reduce_scatter_sched("bine_dd", p),
        "recdoub": lambda p, root: reduce_scatter_sched("recdoub_dd", p),
        "ring": lambda p, root: ring_reduce_scatter_sched(p),
    },
    "allgather": {
        "bine": lambda p, root: allgather_sched("bine_dh", p),
        "recdoub": lambda p, root: allgather_sched("recdoub_dh", p),
        "ring": lambda p, root: ring_allgather_sched(p),
    },
    "allreduce": {
        "bine": lambda p, root: allreduce_large_sched("bine_dd", "bine_dh", p),
        "bine_small": lambda p, root: allreduce_small_sched("bine_dh", p),
        "recdoub": lambda p, root: allreduce_large_sched("recdoub_dd", "recdoub_dh", p),
        "recdoub_small": lambda p, root: allreduce_small_sched("recdoub_dh", p),
        "ring": lambda p, root: ring_allreduce_sched(p),
    },
    "alltoall": {
        # alltoall routing needs the future-cone partition → DD kinds.
        # (every step carries n/2 regardless, so DH vs DD ordering does
        # not change the per-step payload profile.)
        "bine": lambda p, root: alltoall_sched("bine_dd", p),
        "bruck": lambda p, root: bruck_alltoall_sched(p),
        "recdoub": lambda p, root: alltoall_sched("recdoub_dd", p),
    },
}


def get_schedule(collective: str, algo: str, p: int, root: int = 0) -> Sched:
    """Uniform accessor used by the simulator / traffic model / benchmarks."""
    return _REGISTRY[collective][algo](p, root)


def list_algos(collective: str) -> Tuple[str, ...]:
    """Every registered algorithm name for ``collective``."""
    return tuple(_REGISTRY[collective])


COLLECTIVES = (
    "allreduce", "allgather", "reduce_scatter", "alltoall",
    "broadcast", "reduce", "gather", "scatter",
)
