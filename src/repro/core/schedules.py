"""Composable communication-schedule IR (paper Sec. 4) for every algorithm.

A ``Schedule`` is an immutable sequence of steps; each step is a tuple of
``Msg`` records ``(src, dst, blocks)`` plus a per-step *kind* telling every
consumer how the payload transforms buffer state:

  kind        src after send       dst on receive
  "reduce"    deletes the blocks   accumulates (must already hold them)
  "copy"      keeps the blocks     installs (values must agree if held)
  "move"      deletes the blocks   installs

``blocks`` is the ordered tuple of vector-block indices carried by the
message (block = 1/p of the vector for most collectives; for
broadcast/reduce "small" the whole vector is ``(BLOCK_ALL,)`` and counts
as p pseudo-blocks for byte accounting — see ``Msg.nblocks``).

Generators *produce* Schedule values:
  trees       : bine_dh | bine_dd | binomial_dh | binomial_dd
  butterflies : bine_dh | bine_dd | recdoub_dh | recdoub_dd
  linear      : ring, bruck (alltoall baseline; any rank count)

Combinators *transform* them:
  * ``compose(collective, tiers, ...)`` — arbitrary-depth hierarchical
    schedules.  Tier j (innermost first) runs the flat generator inside
    every radix-``tiers[j]`` subgroup, lifted onto the global rank/block
    digit space; ``bine_hier`` is the depth-2 special case.
  * non-pow2 adapters — proxy-rank *folding* (each extra rank folds onto
    a pow2-core proxy) and *3-2 elimination* (one rank per triple retires
    after a two-step pre-reduction, rejoining at the end) wrap any pow2
    generator so every registered (collective, algo) pair passes the
    oracle at arbitrary ``p``.

These schedules are consumed by
  * core.simulate   — numpy execution + oracle checks (kind-driven),
  * core.traffic    — per-link / global-link byte counting,
  * tuner.trace     — per-link replay counters,
  * collectives.shmap — baked in as static ppermute step tables.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple

from . import butterflies as bf
from . import trees as tr
from .negabinary import log2_int

BLOCK_ALL = -1  # sentinel: message carries the full vector

#: per-step kinds (see module docstring for the buffer semantics)
KIND_REDUCE = "reduce"
KIND_COPY = "copy"
KIND_MOVE = "move"
KINDS = (KIND_REDUCE, KIND_COPY, KIND_MOVE)


@dataclass(frozen=True)
class Msg:
    src: int
    dst: int
    blocks: Tuple[int, ...]  # ordered block ids; (BLOCK_ALL,) = whole vector

    def nblocks(self, p: int) -> int:
        if self.blocks == (BLOCK_ALL,):
            return p
        return len(self.blocks)


Step = List[Msg]
Sched = List[Step]  # legacy alias: anything iterable as steps-of-Msg


@dataclass(frozen=True)
class Schedule:
    """The schedule IR value: steps + per-step kinds (+ provenance).

    Behaves as a read-only sequence of steps so every pre-IR consumer
    (``for step in sched``, ``len(sched)``, ``sched[i]``) keeps working;
    ``+`` concatenates phases (reduce_scatter + allgather = allreduce).
    """

    steps: Tuple[Tuple[Msg, ...], ...]
    kinds: Tuple[str, ...]
    collective: str = ""
    p: int = 0
    root: int = 0

    def __post_init__(self):
        if len(self.steps) != len(self.kinds):
            raise ValueError(
                f"{len(self.steps)} steps but {len(self.kinds)} kinds")
        bad = set(self.kinds) - set(KINDS)
        if bad:
            raise ValueError(f"unknown step kinds {sorted(bad)}")

    def __len__(self) -> int:
        return len(self.steps)

    def __iter__(self):
        return iter(self.steps)

    def __getitem__(self, i):
        return self.steps[i]

    def __bool__(self) -> bool:
        return bool(self.steps)

    def __add__(self, other: "Schedule") -> "Schedule":
        if not isinstance(other, Schedule):
            return NotImplemented
        if self.p and other.p and self.p != other.p:
            raise ValueError(f"cannot concatenate schedules for p={self.p} "
                             f"and p={other.p}")
        return Schedule(
            steps=self.steps + other.steps,
            kinds=self.kinds + other.kinds,
            collective=(self.collective
                        if self.collective == other.collective else ""),
            p=self.p or other.p,
            root=self.root if self.root == other.root else 0)


def _sched(steps: Sequence[Sequence[Msg]], kinds, collective: str = "",
           p: int = 0, root: int = 0) -> Schedule:
    steps_t = tuple(tuple(s) for s in steps)
    if isinstance(kinds, str):
        kinds = (kinds,) * len(steps_t)
    return Schedule(steps_t, tuple(kinds), collective, p, root)


def step_kinds(sched, default: str) -> Tuple[str, ...]:
    """Per-step kinds of ``sched``; plain step lists get ``default``."""
    kinds = getattr(sched, "kinds", None)
    if kinds is None:
        kinds = (default,) * len(sched)
    return tuple(kinds)


def _is_pow2(p: int) -> bool:
    return p > 0 and p & (p - 1) == 0


def _fold_q(p: int) -> int:
    """Largest power of two <= p (the pow2 core the adapters wrap)."""
    return 1 << (p.bit_length() - 1)


# ---------------------------------------------------------------------------
# Broadcast / Reduce (small vectors): plain trees (paper Sec. 4.5)
# ---------------------------------------------------------------------------

def broadcast_sched(algo: str, p: int, root: int = 0) -> Schedule:
    tree = tr.rotate_schedule(tr.TREES[algo](p), root, p)
    return _sched([[Msg(a, b, (BLOCK_ALL,)) for a, b in step]
                   for step in tree], KIND_COPY, "broadcast", p, root)


def reduce_sched(algo: str, p: int, root: int = 0) -> Schedule:
    """Reduce = time-reversed broadcast; each edge flows child -> parent."""
    tree = tr.rotate_schedule(tr.TREES[algo](p), root, p)
    return _sched([[Msg(b, a, (BLOCK_ALL,)) for a, b in step]
                   for step in reversed(tree)], KIND_REDUCE, "reduce", p, root)


# ---------------------------------------------------------------------------
# Gather / Scatter: trees with per-subtree block sets (paper Sec. 4.1/4.2)
# ---------------------------------------------------------------------------

def gather_sched(algo: str, p: int, root: int = 0) -> Schedule:
    """Each rank forwards its whole accumulated subtree to its parent.

    Accumulated sets are replayed exactly (order preserved mod-p contiguous
    for bine_dh / binomial trees, per paper Sec. 4.1).
    """
    tree = tr.TREES[algo](p)
    held: List[List[int]] = [[r] for r in range(p)]
    steps: List[Step] = []
    for step in reversed(tree):
        msgs: Step = []
        for parent, child in step:
            msgs.append(Msg(child, parent, tuple(held[child])))
            held[parent] = _merge_mod_contig(held[parent], held[child], p)
        steps.append(msgs)
    assert sorted(held[0]) == list(range(p))
    return _rotate_msgs(_sched(steps, KIND_MOVE, "gather", p), root, p)


def scatter_sched(algo: str, p: int, root: int = 0) -> Schedule:
    """Scatter = time-reversed gather: parent sends child's subtree blocks."""
    g = gather_sched(algo, p, 0)
    steps = [[Msg(m.dst, m.src, m.blocks) for m in step]
             for step in reversed(g.steps)]
    return _rotate_msgs(_sched(steps, KIND_MOVE, "scatter", p), root, p)


def _merge_mod_contig(a: List[int], b: List[int], p: int) -> List[int]:
    """Merge two block lists, keeping mod-p contiguous order when possible."""
    if (a[-1] + 1) % p == b[0] % p:
        return a + b
    if (b[-1] + 1) % p == a[0] % p:
        return b + a
    return a + b  # non-contiguous (bine_dd subtrees) — order by arrival


def _rotate_msgs(sched: Schedule, root: int, p: int) -> Schedule:
    if root % p == 0:
        return sched
    steps = [
        [
            Msg((m.src + root) % p, (m.dst + root) % p,
                tuple((blk + root) % p for blk in m.blocks)
                if m.blocks != (BLOCK_ALL,) else m.blocks)
            for m in step
        ]
        for step in sched.steps
    ]
    return _sched(steps, sched.kinds, sched.collective, p, root)


# ---------------------------------------------------------------------------
# Reduce-scatter / Allgather: vector-halving/-doubling butterflies (Sec. 4.3)
# ---------------------------------------------------------------------------

def reduce_scatter_sched(algo: str, p: int) -> Schedule:
    """Vector-halving butterfly RS.  At step i, r sends the partial sums of
    every block in its partner's next-level cone.

    Result: rank r holds the full sum of block ``final_block(algo)[r]``
    (identity block only after the Sec. 4.3.1 contiguity permutation).
    """
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    cs = bf.cones(algo, p)
    steps: List[Step] = []
    for i in range(s):
        msgs: Step = []
        for r in range(p):
            q = int(tab[i, r])
            msgs.append(Msg(r, q, tuple(sorted(cs[i + 1][q]))))
        steps.append(msgs)
    return _sched(steps, KIND_REDUCE, "reduce_scatter", p)


def allgather_sched(algo: str, p: int) -> Schedule:
    """Vector-doubling butterfly AG: r sends every block it has accumulated."""
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    held: List[List[int]] = [[r] for r in range(p)]
    steps: List[Step] = []
    for i in range(s):
        msgs: Step = []
        snapshot = [list(x) for x in held]
        for r in range(p):
            q = int(tab[i, r])
            assert not set(snapshot[r]) & set(snapshot[q]), (
                algo, p, i, r, "allgather exchange would duplicate blocks")
            msgs.append(Msg(r, q, tuple(snapshot[r])))
        for r in range(p):
            held[r] = snapshot[r] + snapshot[int(tab[i, r])]
        steps.append(msgs)
    for r in range(p):
        assert sorted(held[r]) == list(range(p))
    return _sched(steps, KIND_COPY, "allgather", p)


def allreduce_large_sched(algo_rs: str, algo_ag: str, p: int) -> Schedule:
    """Large-vector allreduce = RS (distance-doubling) + AG (distance-halving).

    Block bookkeeping: the AG must redistribute exactly the blocks the RS
    left behind, so its per-step block sets are the RS cones replayed
    forward.  (paper Sec. 4.4)
    """
    # Block-exact view: the RS leaves rank r holding the full sum of block r
    # (message *contents* may be non-contiguous in buffer space — that is the
    # Sec. 4.3.1 permutation's job, handled positionally in collectives.shmap).
    return reduce_scatter_sched(algo_rs, p) + allgather_sched(algo_ag, p)


def allreduce_small_sched(algo: str, p: int) -> Schedule:
    """Small-vector allreduce: recursive doubling, full vector each step."""
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    steps = [
        [Msg(r, int(tab[i, r]), (BLOCK_ALL,)) for r in range(p)]
        for i in range(s)
    ]
    return _sched(steps, KIND_REDUCE, "allreduce", p)


# ---------------------------------------------------------------------------
# Alltoall: butterfly-routed (Bruck-like, paper Sec. 4.4)
# ---------------------------------------------------------------------------

def alltoall_sched(algo: str, p: int) -> Schedule:
    """Each rank starts with p blocks (one per destination).  At step i it
    forwards to its partner every block whose *destination* lies in the
    partner's next-level cone.  Every block reaches its destination after
    s steps; each step moves exactly p/2 blocks per rank (n/2 bytes).
    """
    s = log2_int(p)
    tab = bf.partner_table(algo, p)
    cs = bf.cones(algo, p)
    # held[r] = list of (dest, origin) pairs currently buffered at r
    held: List[List[Tuple[int, int]]] = [
        [(d, r) for d in range(p)] for r in range(p)
    ]
    steps: List[Step] = []
    for i in range(s):
        msgs: Step = []
        moved: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        kept: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        for r in range(p):
            q = int(tab[i, r])
            qcone = cs[i + 1][q]
            send = [x for x in held[r] if x[0] in qcone]
            keep = [x for x in held[r] if x[0] not in qcone]
            # encode (dest, origin) pairs as dest*p + origin (uniform n/p size)
            msgs.append(Msg(r, q, tuple(d * p + o for d, o in send)))
            moved[q].extend(send)
            kept[r] = keep
        for r in range(p):
            held[r] = kept[r] + moved[r]
        steps.append(msgs)
    for r in range(p):
        assert sorted(d for d, _ in held[r]) == [r] * p
        assert sorted(o for _, o in held[r]) == list(range(p))
    return _sched(steps, KIND_MOVE, "alltoall", p)


def bruck_alltoall_sched(p: int) -> Schedule:
    """Classical Bruck alltoall baseline: step i sends, to rank r - 2**i,
    every block whose relative destination distance has bit i set.

    Defined for any rank count: the remaining travel distance
    ``(r - dest) mod p`` is < p, so its ceil(log2 p) bits route every
    block — each hop of -2**i clears bit i exactly (no carries), which
    is what makes the construction rank-count agnostic.  Ranks with no
    bit-i blocks just skip step i.
    """
    s = (p - 1).bit_length()
    held: List[List[Tuple[int, int]]] = [
        [(d, r) for d in range(p)] for r in range(p)
    ]
    steps: List[Step] = []
    for i in range(s):
        msgs: Step = []
        moved: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        kept: List[List[Tuple[int, int]]] = [[] for _ in range(p)]
        for r in range(p):
            q = (r - (1 << i)) % p
            send = [x for x in held[r] if ((r - x[0]) % p) >> i & 1]
            keep = [x for x in held[r] if not ((r - x[0]) % p) >> i & 1]
            if send:
                msgs.append(Msg(r, q, tuple(d * p + o for d, o in send)))
            moved[q].extend(send)
            kept[r] = keep
        for r in range(p):
            held[r] = kept[r] + moved[r]
        if msgs:
            steps.append(msgs)
    for r in range(p):
        assert sorted(d for d, _ in held[r]) == [r] * p
    return _sched(steps, KIND_MOVE, "alltoall", p)


# ---------------------------------------------------------------------------
# Ring baselines (defined for any rank count)
# ---------------------------------------------------------------------------

def ring_reduce_scatter_sched(p: int) -> Schedule:
    """p-1 steps; step t: rank r sends partial block (r-t-1) mod p to r+1.

    Block b hops b+1 → b+2 → … → b, accumulating every contribution, so
    rank r ends holding the full sum of its own block r.
    """
    steps = [[Msg(r, (r + 1) % p, ((r - t - 1) % p,)) for r in range(p)]
             for t in range(p - 1)]
    return _sched(steps, KIND_REDUCE, "reduce_scatter", p)


def ring_allgather_sched(p: int) -> Schedule:
    steps = [[Msg(r, (r + 1) % p, ((r - t) % p,)) for r in range(p)]
             for t in range(p - 1)]
    return _sched(steps, KIND_COPY, "allgather", p)


def ring_allreduce_sched(p: int) -> Schedule:
    """Ring RS + ring AG (2(p-1) steps)."""
    return ring_reduce_scatter_sched(p) + ring_allgather_sched(p)


# ---------------------------------------------------------------------------
# Composite large-vector bcast / reduce (paper Sec. 4.5)
# ---------------------------------------------------------------------------

def broadcast_large_sched(family: str, p: int, root: int = 0) -> Schedule:
    """scatter (distance-doubling tree) + allgather (distance-halving bfly)."""
    if family == "bine":
        sc = _np2_scatter("bine_dd", p, root)
        ag = _np2_allgather("bine_dh", p)
    else:
        sc = _np2_scatter("binomial_dh", p, root)   # MPICH-style
        ag = _np2_allgather("recdoub_dd", p)
    return sc + ag


def reduce_large_sched(family: str, p: int, root: int = 0) -> Schedule:
    """reduce-scatter (distance-doubling bfly) + gather (dist-halving tree)."""
    if family == "bine":
        rs = _np2_reduce_scatter("bine_dd", p)
        ga = _np2_gather("bine_dh", p, root)
    else:
        rs = _np2_reduce_scatter("recdoub_dd", p)
        ga = _np2_gather("binomial_dh", p, root)
    return rs + ga


# ---------------------------------------------------------------------------
# Non-pow2 adapters: proxy-rank folding and 3-2 elimination
# ---------------------------------------------------------------------------
#
# Folding: extras e_k = q + k (k < rem, q = 2**floor(log2 p)) fold their
# contribution onto proxy rank k before a pow2 schedule over ranks 0..q-1,
# and receive their result afterwards.  Virtual block k expands to the real
# block set {k, q+k}; every other virtual block is itself.
#
# 3-2 elimination (butterfly collectives, needs 3*rem <= p): rank c = 3k+2
# of each triple (3k, 3k+1, 3k+2) pre-reduces one half of the vector onto
# each surviving neighbor over two steps, sits out the pow2 core over the
# q survivors, and rejoins at the end.  Max pre/post message is n/2 vs the
# fold's full-vector n.

def _fold_blocks(p: int) -> Callable[[int], Tuple[int, ...]]:
    q = _fold_q(p)
    rem = p - q
    def blocks_of(vb: int) -> Tuple[int, ...]:
        return (vb, q + vb) if vb < rem else (vb,)
    return blocks_of


def _elim_maps(p: int):
    q = _fold_q(p)
    rem = p - q
    gone = tuple(3 * k + 2 for k in range(rem))
    gset = set(gone)
    surv = tuple(r for r in range(p) if r not in gset)
    def blocks_of(w: int) -> Tuple[int, ...]:
        r = surv[w]
        if r % 3 == 0 and r // 3 < rem:
            return (r, r + 2)
        return (r,)
    return q, rem, surv, blocks_of


def _lift(sched: Schedule, rank_of: Callable[[int], int],
          blocks_of: Callable[[int], Tuple[int, ...]]):
    """Relabel a virtual schedule onto real ranks/blocks."""
    steps = []
    for step in sched.steps:
        out = []
        for m in step:
            blocks = (m.blocks if m.blocks == (BLOCK_ALL,) else
                      tuple(b for vb in m.blocks for b in blocks_of(vb)))
            out.append(Msg(rank_of(m.src), rank_of(m.dst), blocks))
        steps.append(out)
    return steps, list(sched.kinds)


def _halves(p: int):
    return tuple(range(p // 2)), tuple(range(p // 2, p))


def _fold_reduce_scatter(build, p: int) -> Schedule:
    q = _fold_q(p)
    rem = p - q
    steps, kinds = _lift(build(q), lambda r: r, _fold_blocks(p))
    pre = [Msg(q + k, k, tuple(range(p))) for k in range(rem)]
    post = [Msg(k, q + k, (q + k,)) for k in range(rem)]
    return _sched([pre] + steps + [post],
                  [KIND_REDUCE] + kinds + [KIND_MOVE], "reduce_scatter", p)


def _elim_reduce_scatter(build, p: int) -> Schedule:
    q, rem, surv, blocks_of = _elim_maps(p)
    steps, kinds = _lift(build(q), lambda w: surv[w], blocks_of)
    h1, h2 = _halves(p)
    pre1 = [Msg(3 * k + 2, 3 * k + 1, h1) for k in range(rem)]
    pre2 = [Msg(3 * k + 2, 3 * k, h2) for k in range(rem)]
    post = [Msg(3 * k, 3 * k + 2, (3 * k + 2,)) for k in range(rem)]
    return _sched([pre1, pre2] + steps + [post],
                  [KIND_REDUCE, KIND_REDUCE] + kinds + [KIND_MOVE],
                  "reduce_scatter", p)


def _fold_allgather(build, p: int) -> Schedule:
    q = _fold_q(p)
    rem = p - q
    steps, kinds = _lift(build(q), lambda r: r, _fold_blocks(p))
    pre = [Msg(q + k, k, (q + k,)) for k in range(rem)]
    post = [Msg(k, q + k, tuple(range(p))) for k in range(rem)]
    return _sched([pre] + steps + [post],
                  [KIND_COPY] + kinds + [KIND_COPY], "allgather", p)


def _elim_allgather(build, p: int) -> Schedule:
    q, rem, surv, blocks_of = _elim_maps(p)
    steps, kinds = _lift(build(q), lambda w: surv[w], blocks_of)
    h1, h2 = _halves(p)
    pre = [Msg(3 * k + 2, 3 * k, (3 * k + 2,)) for k in range(rem)]
    post1 = [Msg(3 * k + 1, 3 * k + 2, h1) for k in range(rem)]
    post2 = [Msg(3 * k, 3 * k + 2, h2) for k in range(rem)]
    return _sched([pre] + steps + [post1, post2],
                  [KIND_COPY] + kinds + [KIND_COPY, KIND_COPY],
                  "allgather", p)


def _fold_allreduce(build, p: int) -> Schedule:
    q = _fold_q(p)
    rem = p - q
    steps, kinds = _lift(build(q), lambda r: r, _fold_blocks(p))
    pre = [Msg(q + k, k, tuple(range(p))) for k in range(rem)]
    post = [Msg(k, q + k, tuple(range(p))) for k in range(rem)]
    return _sched([pre] + steps + [post],
                  [KIND_REDUCE] + kinds + [KIND_COPY], "allreduce", p)


def _elim_allreduce(build, p: int) -> Schedule:
    q, rem, surv, blocks_of = _elim_maps(p)
    steps, kinds = _lift(build(q), lambda w: surv[w], blocks_of)
    h1, h2 = _halves(p)
    pre1 = [Msg(3 * k + 2, 3 * k + 1, h1) for k in range(rem)]
    pre2 = [Msg(3 * k + 2, 3 * k, h2) for k in range(rem)]
    post1 = [Msg(3 * k + 1, 3 * k + 2, h1) for k in range(rem)]
    post2 = [Msg(3 * k, 3 * k + 2, h2) for k in range(rem)]
    return _sched([pre1, pre2] + steps + [post1, post2],
                  [KIND_REDUCE, KIND_REDUCE] + kinds
                  + [KIND_COPY, KIND_COPY], "allreduce", p)


def _adapt(fold, elim, build, p: int) -> Schedule:
    """Route a pow2 ``build`` through the cheapest applicable adapter."""
    if _is_pow2(p):
        return build(p)
    rem = p - _fold_q(p)
    if elim is not None and 3 * rem <= p:
        return elim(build, p)
    return fold(build, p)


def _np2_reduce_scatter(kind: str, p: int) -> Schedule:
    return _adapt(_fold_reduce_scatter, _elim_reduce_scatter,
                  lambda q: reduce_scatter_sched(kind, q), p)


def _np2_allgather(kind: str, p: int) -> Schedule:
    return _adapt(_fold_allgather, _elim_allgather,
                  lambda q: allgather_sched(kind, q), p)


def _np2_allreduce_large(kind_rs: str, kind_ag: str, p: int) -> Schedule:
    return _adapt(_fold_allreduce, _elim_allreduce,
                  lambda q: allreduce_large_sched(kind_rs, kind_ag, q), p)


def _np2_allreduce_small(kind: str, p: int) -> Schedule:
    if _is_pow2(p):
        return allreduce_small_sched(kind, p)
    q = _fold_q(p)
    rem = p - q
    steps, kinds = _lift(allreduce_small_sched(kind, q),
                         lambda r: r, lambda vb: (vb,))
    pre = [Msg(q + k, k, (BLOCK_ALL,)) for k in range(rem)]
    post = [Msg(k, q + k, (BLOCK_ALL,)) for k in range(rem)]
    return _sched([pre] + steps + [post],
                  [KIND_REDUCE] + kinds + [KIND_COPY], "allreduce", p)


def _np2_broadcast(kind: str, p: int, root: int) -> Schedule:
    if _is_pow2(p):
        return broadcast_sched(kind, p, root)
    q = _fold_q(p)
    rem = p - q
    base = broadcast_sched(kind, q, 0)
    post = [Msg(k, q + k, (BLOCK_ALL,)) for k in range(rem)]
    out = _sched(list(base.steps) + [post],
                 list(base.kinds) + [KIND_COPY], "broadcast", p)
    return _rotate_msgs(out, root, p)


def _np2_reduce(kind: str, p: int, root: int) -> Schedule:
    if _is_pow2(p):
        return reduce_sched(kind, p, root)
    q = _fold_q(p)
    rem = p - q
    base = reduce_sched(kind, q, 0)
    pre = [Msg(q + k, k, (BLOCK_ALL,)) for k in range(rem)]
    out = _sched([pre] + list(base.steps),
                 [KIND_REDUCE] + list(base.kinds), "reduce", p)
    return _rotate_msgs(out, root, p)


def _np2_gather(kind: str, p: int, root: int) -> Schedule:
    if _is_pow2(p):
        return gather_sched(kind, p, root)
    q = _fold_q(p)
    rem = p - q
    steps, kinds = _lift(gather_sched(kind, q, 0),
                         lambda r: r, _fold_blocks(p))
    pre = [Msg(q + k, k, (q + k,)) for k in range(rem)]
    out = _sched([pre] + steps, [KIND_MOVE] + kinds, "gather", p)
    return _rotate_msgs(out, root, p)


def _np2_scatter(kind: str, p: int, root: int) -> Schedule:
    if _is_pow2(p):
        return scatter_sched(kind, p, root)
    q = _fold_q(p)
    rem = p - q
    steps, kinds = _lift(scatter_sched(kind, q, 0),
                         lambda r: r, _fold_blocks(p))
    post = [Msg(k, q + k, (q + k,)) for k in range(rem)]
    out = _sched(steps + [post], kinds + [KIND_MOVE], "scatter", p)
    return _rotate_msgs(out, root, p)


def _np2_alltoall(kind: str, p: int) -> Schedule:
    """Fold alltoall: (dest, origin) keys lift through {v, q+v} on both
    axes; extras hand their whole buffer to the proxy first and receive
    every pair addressed to them at the end."""
    if _is_pow2(p):
        return alltoall_sched(kind, p)
    q = _fold_q(p)
    rem = p - q
    def reps(v: int) -> Tuple[int, ...]:
        return (v, q + v) if v < rem else (v,)
    virt = alltoall_sched(kind, q)
    steps = []
    for step in virt.steps:
        out = []
        for m in step:
            blocks = tuple(d * p + o for key in m.blocks
                           for d in reps(key // q) for o in reps(key % q))
            out.append(Msg(m.src, m.dst, blocks))
        steps.append(out)
    pre = [Msg(q + k, k, tuple(d * p + (q + k) for d in range(p)))
           for k in range(rem)]
    post = [Msg(k, q + k, tuple((q + k) * p + o for o in range(p)))
            for k in range(rem)]
    return _sched([pre] + steps + [post], KIND_MOVE, "alltoall", p)


# ---------------------------------------------------------------------------
# compose: arbitrary-depth hierarchical schedules (the bine_hier combinator)
# ---------------------------------------------------------------------------

#: compose-able collectives (butterfly family; rooted trees are flat)
COMPOSABLE = ("reduce_scatter", "allgather", "allreduce")


def _tier_schedule(collective: str, algo: str, pt: int) -> Schedule:
    """Flat tier schedule at radix ``pt`` (non-pow2 tiers route through
    the adapters, so mixed-radix hierarchies compose too)."""
    if collective == "reduce_scatter":
        if algo == "ring":
            return ring_reduce_scatter_sched(pt)
        return _np2_reduce_scatter(f"{algo}_dd", pt)
    if collective == "allgather":
        if algo == "ring":
            return ring_allgather_sched(pt)
        return _np2_allgather(f"{algo}_dh", pt)
    raise ValueError(f"no tier schedule for {collective!r}")


def _compose_steps(collective: str, tiers: Tuple[int, ...], algo: str):
    """Lift the flat tier-``j`` schedule onto the global digit space.

    Ranks and blocks share one mixed-radix numeral system: digit j of
    rank r has stride ``prod(tiers[:j])`` (innermost tier = digit 0, so
    consecutive ranks share the innermost subgroup).  Phase j runs the
    flat schedule over digit j inside every subgroup (= fixed assignment
    of the other digits); virtual block vb expands to every block whose
    digit j is vb, whose digits < j match the subgroup, and whose digits
    > j are free — the phases already run settled those, the later phases
    will fan the rest out.  RS runs phases innermost→outermost; AG is the
    mirror.  Each lifted step is a union of per-subgroup partial
    permutations over disjoint rank sets, so it is itself a valid step.
    """
    d = len(tiers)
    strides, acc = [], 1
    for t in tiers:
        strides.append(acc)
        acc *= t
    order = range(d) if collective == "reduce_scatter" else range(d - 1, -1, -1)
    steps, kinds = [], []
    for j in order:
        pt = tiers[j]
        if pt == 1:
            continue
        virt = _tier_schedule(collective, algo, pt)
        stride = strides[j]
        free = [0]
        for i in range(j + 1, d):
            free = [f + c * strides[i] for f in free for c in range(tiers[i])]
        # (rank offset, block low-digit offset) per subgroup
        combos = [(0, 0)]
        for i in range(d):
            if i == j:
                continue
            combos = [(tot + c * strides[i],
                       low + (c * strides[i] if i < j else 0))
                      for tot, low in combos for c in range(tiers[i])]
        for step, kind in zip(virt.steps, virt.kinds):
            real = []
            for tot, low in combos:
                for m in step:
                    assert BLOCK_ALL not in m.blocks
                    blocks = tuple(low + vb * stride + off
                                   for vb in m.blocks for off in free)
                    real.append(Msg(tot + m.src * stride,
                                    tot + m.dst * stride, blocks))
            steps.append(real)
            kinds.append(kind)
    return steps, kinds


def compose(collective: str, tiers: Sequence[int],
            algo: str = "bine") -> Schedule:
    """Hierarchical composition of flat generators over ``tiers``
    (innermost first): ``compose("allreduce", (inner, outer))`` is the
    two-level bine_hier; any depth works, and block ownership matches the
    flat schedule (rank r ends holding block r after reduce_scatter)."""
    tiers = tuple(int(t) for t in tiers)
    if not tiers or any(t < 1 for t in tiers):
        raise ValueError(f"tiers must be positive, got {tiers!r}")
    p = 1
    for t in tiers:
        p *= t
    if collective == "allreduce":
        return (compose("reduce_scatter", tiers, algo)
                + compose("allgather", tiers, algo))
    if collective not in COMPOSABLE:
        raise ValueError(
            f"compose supports {COMPOSABLE}, not {collective!r}")
    steps, kinds = _compose_steps(collective, tiers, algo)
    return _sched(steps, kinds, collective, p)


def default_tiers(p: int) -> Tuple[int, ...]:
    """Topology-agnostic balanced two-tier pow2 split, innermost first
    (p=8 → (4, 2), p=16 → (4, 4)); presets refine this via
    ``repro.topology.tier_split``."""
    s = log2_int(p)
    inner = 1 << ((s + 1) // 2)
    return tuple(t for t in (inner, p // inner) if t > 1) or (p,)


def hier_schedule(collective: str, p: int, algo: str = "bine",
                  tiers: Sequence[int] = None) -> Schedule:
    """The registered ``bine_hier`` builder: ``compose`` over ``tiers``
    (default: ``default_tiers``), with non-pow2 ``p`` handled by wrapping
    the composed pow2-core schedule in the fold/elimination adapters."""
    if collective not in COMPOSABLE:
        raise ValueError(
            f"hier_schedule supports {COMPOSABLE}, not {collective!r}")
    if tiers is not None:
        return compose(collective, tiers, algo)
    build = lambda q: compose(collective, default_tiers(q), algo)
    fold, elim = {
        "reduce_scatter": (_fold_reduce_scatter, _elim_reduce_scatter),
        "allgather": (_fold_allgather, _elim_allgather),
        "allreduce": (_fold_allreduce, _elim_allreduce),
    }[collective]
    return _adapt(fold, elim, build, p)


# ---------------------------------------------------------------------------
# Registry: collective -> {algorithm-name -> schedule builder}
# ---------------------------------------------------------------------------

#: collective -> algo -> builder(p, root).  The module-level registry lets
#: tests enumerate every (collective, algo) pair (``list_algos``) so the
#: conformance matrix covers pairs added later automatically.  Every
#: builder accepts arbitrary p: pow2 builds are the flat generators,
#: anything else routes through the fold / 3-2 elimination adapters
#: (rings and bruck are rank-count agnostic natively).
_REGISTRY: Dict[str, Dict[str, Any]] = {
    "broadcast": {
        "bine": lambda p, root: _np2_broadcast("bine_dh", p, root),
        "binomial_dh": lambda p, root: _np2_broadcast("binomial_dh", p, root),
        "binomial_dd": lambda p, root: _np2_broadcast("binomial_dd", p, root),
        "bine_large": lambda p, root: broadcast_large_sched("bine", p, root),
        "binomial_large": lambda p, root: broadcast_large_sched("binomial", p, root),
    },
    "reduce": {
        "bine": lambda p, root: _np2_reduce("bine_dh", p, root),
        "binomial_dh": lambda p, root: _np2_reduce("binomial_dh", p, root),
        "binomial_dd": lambda p, root: _np2_reduce("binomial_dd", p, root),
        "bine_large": lambda p, root: reduce_large_sched("bine", p, root),
        "binomial_large": lambda p, root: reduce_large_sched("binomial", p, root),
    },
    "gather": {
        "bine": lambda p, root: _np2_gather("bine_dh", p, root),
        "binomial": lambda p, root: _np2_gather("binomial_dh", p, root),
    },
    "scatter": {
        # standalone scatter reverses the dh gather (Sec. 4.2); the
        # dd variant exists for the composite large-vector broadcast
        "bine": lambda p, root: _np2_scatter("bine_dh", p, root),
        "bine_dd": lambda p, root: _np2_scatter("bine_dd", p, root),
        "binomial": lambda p, root: _np2_scatter("binomial_dh", p, root),
    },
    "reduce_scatter": {
        "bine": lambda p, root: _np2_reduce_scatter("bine_dd", p),
        "recdoub": lambda p, root: _np2_reduce_scatter("recdoub_dd", p),
        "ring": lambda p, root: ring_reduce_scatter_sched(p),
        "bine_hier": lambda p, root: hier_schedule("reduce_scatter", p),
    },
    "allgather": {
        "bine": lambda p, root: _np2_allgather("bine_dh", p),
        "recdoub": lambda p, root: _np2_allgather("recdoub_dh", p),
        "ring": lambda p, root: ring_allgather_sched(p),
        "bine_hier": lambda p, root: hier_schedule("allgather", p),
    },
    "allreduce": {
        "bine": lambda p, root: _np2_allreduce_large("bine_dd", "bine_dh", p),
        "bine_small": lambda p, root: _np2_allreduce_small("bine_dh", p),
        "recdoub": lambda p, root: _np2_allreduce_large("recdoub_dd", "recdoub_dh", p),
        "recdoub_small": lambda p, root: _np2_allreduce_small("recdoub_dh", p),
        "ring": lambda p, root: ring_allreduce_sched(p),
        "bine_hier": lambda p, root: hier_schedule("allreduce", p),
    },
    "alltoall": {
        # alltoall routing needs the future-cone partition → DD kinds.
        # (every step carries n/2 regardless, so DH vs DD ordering does
        # not change the per-step payload profile.)
        "bine": lambda p, root: _np2_alltoall("bine_dd", p),
        "bruck": lambda p, root: bruck_alltoall_sched(p),
        "recdoub": lambda p, root: _np2_alltoall("recdoub_dd", p),
    },
}


def get_schedule(collective: str, algo: str, p: int, root: int = 0) -> Schedule:
    """Uniform accessor used by the simulator / traffic model / benchmarks."""
    return _REGISTRY[collective][algo](p, root)


def list_algos(collective: str) -> Tuple[str, ...]:
    """Every registered algorithm name for ``collective``."""
    return tuple(_REGISTRY[collective])


COLLECTIVES = (
    "allreduce", "allgather", "reduce_scatter", "alltoall",
    "broadcast", "reduce", "gather", "scatter",
)
