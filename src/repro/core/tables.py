"""Static lookup tables baked into the SPMD (shard_map) collectives.

Everything here is plain numpy, derived from the verified schedules in
``core.schedules``.  The JAX layer indexes these tables with
``lax.axis_index`` at trace time, so every per-rank decision (which half to
keep, where to place an incoming window, ...) becomes one table lookup and
the communication itself is a static ``ppermute`` permutation list.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Tuple

import numpy as np

from . import butterflies as bf
from . import schedules as sc
from .negabinary import log2_int, reverse_bits, v_table


# ---------------------------------------------------------------------------
# Butterfly tables (reduce-scatter / allgather / allreduce-large / small)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ButterflyTables:
    """All static data for a vector-halving/-doubling butterfly on p ranks.

    Offsets are in *block* units (block = vec/p); the JAX layer multiplies
    by the per-block element count.
    """
    p: int
    s: int
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]  # [s] ppermute pair lists
    keep_off: np.ndarray    # [s, p] kept-half block offset at RS step i
    send_off: np.ndarray    # [s, p] sent-half block offset at RS step i
    cbit: np.ndarray        # [s, p] half-choice bit (0 = lower half kept)
    final_block: np.ndarray  # [p] position-block held after RS (= reverse(v))
    inv_final: np.ndarray   # [p] inverse permutation


@lru_cache(maxsize=None)
def butterfly_tables(kind: str, p: int) -> ButterflyTables:
    s = log2_int(p)
    tab = bf.partner_table(kind, p)
    c = bf.half_choice(kind, p)
    keep = bf.rs_offsets(kind, p)
    half = np.array([p >> (i + 1) for i in range(s)])[:, None]
    send = keep + (1 - 2 * c) * half
    fb = bf.final_block(kind, p)
    inv = np.argsort(fb)
    perms = tuple(
        tuple((r, int(tab[i, r])) for r in range(p)) for i in range(s)
    )
    return ButterflyTables(p, s, perms, keep, send, c, fb, inv)


@lru_cache(maxsize=None)
def small_butterfly_perms(kind: str, p: int) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """Pair lists for full-vector recursive-doubling exchange (allreduce small)."""
    s = log2_int(p)
    tab = bf.partner_table(kind, p)
    return tuple(tuple((r, int(tab[i, r])) for r in range(p)) for i in range(s))


# ---------------------------------------------------------------------------
# Tree tables (broadcast / reduce, small vectors)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TreeTables:
    p: int
    s: int
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]  # bcast direction per step
    recv_step: np.ndarray  # [p] step at which rank receives (-1 for root)


@lru_cache(maxsize=None)
def tree_tables(algo: str, p: int, root: int = 0) -> TreeTables:
    from . import trees as tr
    sched = tr.rotate_schedule(tr.TREES[algo](p), root, p)
    s = len(sched)
    recv_step = np.full(p, -1, dtype=np.int64)
    perms = []
    for i, step in enumerate(sched):
        perms.append(tuple(step))
        for _, dst in step:
            assert recv_step[dst] == -1
            recv_step[dst] = i
    assert (recv_step >= 0).sum() == p - 1
    return TreeTables(p, s, tuple(perms), recv_step)


# ---------------------------------------------------------------------------
# Gather / Scatter window tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GatherTables:
    """Local-window bookkeeping for tree gather/scatter.

    Each rank owns a p-block local buffer; local position t of rank r holds
    the block at *position-space* index (anchor[r] + t) mod p, where
    position space is block space mapped through ``posmap`` (identity for
    distance-halving trees; reverse(v(·)) for distance-doubling trees,
    the paper's Sec. 4.3.1 contiguity permutation).
    """
    p: int
    s: int
    posmap: np.ndarray        # [p] block -> position
    anchor: np.ndarray        # [p] per-rank window anchor (position space)
    own_local: np.ndarray     # [p] local offset of rank's own block
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]   # gather direction
    sizes: Tuple[int, ...]    # [s] blocks moved per message at step j
    recv_off: np.ndarray      # [s, p] local offset where receiver places data
    recv_mask: np.ndarray     # [s, p] bool: rank receives at step j
    send_mask: np.ndarray     # [s, p] bool: rank sends at step j
    root_unrot: np.ndarray    # [p] out[k] = local[root_unrot[k]] at the root


@lru_cache(maxsize=None)
def gather_tables(algo: str, p: int, root: int = 0) -> GatherTables:
    """Derived by replaying the verified gather schedule in position space.

    Non-zero roots reuse the root-0 replay with the paper's logical rotation
    (position space is abstract, so only rank/block indexing rotates).
    """
    if root % p != 0:
        t0 = gather_tables(algo, p, 0)
        rot = (np.arange(p) - root) % p
        return GatherTables(
            p, t0.s,
            posmap=t0.posmap[rot],
            anchor=t0.anchor[rot],
            own_local=t0.own_local[rot],
            perms=tuple(tuple(((a + root) % p, (b + root) % p) for a, b in st)
                        for st in t0.perms),
            sizes=t0.sizes,
            recv_off=t0.recv_off[:, rot],
            recv_mask=t0.recv_mask[:, rot],
            send_mask=t0.send_mask[:, rot],
            root_unrot=t0.root_unrot[rot],
        )
    s = log2_int(p)
    sched = sc.gather_sched(algo, p, 0)
    if algo in ("bine_dd",):
        posmap = np.array([reverse_bits(int(v), s) for v in v_table(p)])
    else:
        posmap = np.arange(p)
    # replay: windows in position space, tracked as (start, length) mod p
    win: List[Tuple[int, int]] = [(int(posmap[r]), 1) for r in range(p)]
    send_anchor = np.full(p, -1, dtype=np.int64)
    sizes: List[int] = []
    perms: List[Tuple[Tuple[int, int], ...]] = []
    recv_off = np.zeros((len(sched), p), dtype=np.int64)
    recv_mask = np.zeros((len(sched), p), dtype=bool)
    send_mask = np.zeros((len(sched), p), dtype=bool)
    for j, step in enumerate(sched):
        size = None
        pairs = []
        for m in step:
            src, dst = m.src, m.dst
            pos = [int(posmap[b]) for b in m.blocks]
            st, ln = win[src]
            # sent blocks must be exactly the sender's contiguous window
            assert ln == len(pos), (algo, p, j, src)
            assert sorted((q - st) % p for q in pos) == list(range(ln)), (
                algo, p, j, src, "window not contiguous in position space")
            size = ln if size is None else size
            assert size == ln, "non-uniform message size within a step"
            send_anchor[src] = st
            pairs.append((src, dst))
            # merge into receiver window
            dst_st, dst_ln = win[dst]
            if (dst_st + dst_ln) % p == st:          # extend upward
                win[dst] = (dst_st, dst_ln + ln)
            elif (st + ln) % p == dst_st:            # extend downward
                win[dst] = (st, dst_ln + ln)
            else:
                raise AssertionError((algo, p, j, "windows not adjacent"))
            recv_mask[j, dst] = True
            send_mask[j, src] = True
        sizes.append(size)
        perms.append(tuple(pairs))
    # anchors: send-time window start; root (never sends): final window start
    anchor = send_anchor.copy()
    anchor[root] = win[root][0]
    assert win[root][1] == p
    # incoming placement offsets relative to the receiver's anchor
    win2: List[Tuple[int, int]] = [(int(posmap[r]), 1) for r in range(p)]
    for j, step in enumerate(sched):
        for m in step:
            src, dst = m.src, m.dst
            st, ln = win2[src]
            recv_off[j, dst] = (st - anchor[dst]) % p
            assert recv_off[j, dst] + ln <= p
            dst_st, dst_ln = win2[dst]
            if (dst_st + dst_ln) % p == st:
                win2[dst] = (dst_st, dst_ln + ln)
            else:
                win2[dst] = (st, dst_ln + ln)
    own_local = np.array([(int(posmap[r]) - anchor[r]) % p for r in range(p)])
    root_unrot = np.array([(int(posmap[b]) - anchor[root]) % p for b in range(p)])
    return GatherTables(
        p, len(sched), posmap, anchor, own_local, tuple(perms), tuple(sizes),
        recv_off, recv_mask, send_mask, root_unrot)


@dataclass(frozen=True)
class ScatterTables:
    p: int
    s: int
    posmap: np.ndarray
    root_rot: np.ndarray      # [p] pre-rotation at root: local[t] = x[root_rot[t]]
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]
    sizes: Tuple[int, ...]
    send_off: np.ndarray      # [s, p] local offset of the outgoing window
    recv_mask: np.ndarray
    send_mask: np.ndarray
    own_local: np.ndarray     # [p] where the own block lands locally


@lru_cache(maxsize=None)
def scatter_tables(algo: str, p: int, root: int = 0) -> ScatterTables:
    """Scatter = reversed gather; every rank receives its subtree window once
    (placed at local offset 0 — anchor = subtree window start), then carves
    halves off it."""
    if root % p != 0:
        t0 = scatter_tables(algo, p, 0)
        rot = (np.arange(p) - root) % p
        return ScatterTables(
            p, t0.s,
            posmap=t0.posmap[rot],
            root_rot=(t0.root_rot + root) % p,
            perms=tuple(tuple(((a + root) % p, (b + root) % p) for a, b in st)
                        for st in t0.perms),
            sizes=t0.sizes,
            send_off=t0.send_off[:, rot],
            recv_mask=t0.recv_mask[:, rot],
            send_mask=t0.send_mask[:, rot],
            own_local=t0.own_local[rot],
        )
    s = log2_int(p)
    sched = sc.scatter_sched(algo, p, 0)
    if algo in ("bine_dd",):
        posmap = np.array([reverse_bits(int(v), s) for v in v_table(p)])
    else:
        posmap = np.arange(p)
    # window at receive time = rank's full subtree
    win: Dict[int, Tuple[int, int]] = {}
    sizes: List[int] = []
    perms: List[Tuple[Tuple[int, int], ...]] = []
    nsteps = len(sched)
    send_off = np.zeros((nsteps, p), dtype=np.int64)
    recv_mask = np.zeros((nsteps, p), dtype=bool)
    send_mask = np.zeros((nsteps, p), dtype=bool)
    anchor = np.full(p, -1, dtype=np.int64)

    # root's initial window: all p blocks; anchor chosen so that every block
    # is reachable without wrap: use the root's gather anchor (same window).
    g = gather_tables(algo, p, root)
    anchor[root] = g.anchor[root]
    win[root] = (int(anchor[root]), p)

    for j, step in enumerate(sched):
        size = None
        pairs = []
        for m in step:
            src, dst = m.src, m.dst
            pos = sorted(int(posmap[b]) for b in m.blocks)
            ln = len(pos)
            size = ln if size is None else size
            assert size == ln
            st0, l0 = win[src]
            offs = sorted((q - st0) % p for q in pos)
            assert offs == list(range(offs[0], offs[0] + ln)), (
                algo, p, j, "scatter send not contiguous")
            lo_pos = (st0 + offs[0]) % p
            send_off[j, src] = (lo_pos - anchor[src]) % p
            # sender keeps the other part of its window
            if offs[0] == 0:
                win[src] = ((st0 + ln) % p, l0 - ln)
            else:
                assert offs[0] + ln == l0, "sent chunk not at window edge"
                win[src] = (st0, l0 - ln)
            anchor[dst] = lo_pos
            win[dst] = (lo_pos, ln)
            recv_mask[j, dst] = True
            send_mask[j, src] = True
            pairs.append((src, dst))
        sizes.append(size)
        perms.append(tuple(pairs))
    own_local = np.array([(int(posmap[r]) - anchor[r]) % p for r in range(p)])
    root_rot = np.array([np.argmax(posmap == (anchor[root] + t) % p)
                         for t in range(p)], dtype=np.int64)
    return ScatterTables(
        p, nsteps, posmap, root_rot, tuple(perms), tuple(sizes), send_off,
        recv_mask, send_mask, own_local)


# ---------------------------------------------------------------------------
# Alltoall slot tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AlltoallTables:
    p: int
    s: int
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]
    send_slots: np.ndarray   # [s, p, p//2] local slot ids to send at step i
    recv_slots: np.ndarray   # [s, p, p//2] local slot ids receiving at step i
    final_slots: np.ndarray  # [p, p] out[origin o] = buf[final_slots[r, o]]
    send_contig: bool        # whether every send slot list is a contiguous run


@lru_cache(maxsize=None)
def alltoall_tables(algo: str, p: int) -> AlltoallTables:
    """Slot-level replay of the alltoall schedule.

    Local buffer slot d initially holds the block destined to rank d.
    Received chunks overwrite the slots just vacated by the send (send and
    recv sizes are both p/2 every step, so occupancy stays exact).
    """
    s = log2_int(p)
    if algo == "bruck":
        sched = sc.bruck_alltoall_sched(p)
    else:
        sched = sc.alltoall_sched(algo, p)
    # slot_content[r][t] = (dest, origin) key at local slot t of rank r
    slot: List[List[Tuple[int, int]]] = [
        [(d, r) for d in range(p)] for r in range(p)
    ]
    nsteps = len(sched)
    send_slots = np.zeros((nsteps, p, p // 2), dtype=np.int64)
    recv_slots = np.zeros((nsteps, p, p // 2), dtype=np.int64)
    perms = []
    contig = True
    for j, step in enumerate(sched):
        pairs = []
        incoming: Dict[int, List[Tuple[int, int]]] = {}
        vacated: Dict[int, List[int]] = {}
        for m in step:
            src, dst = m.src, m.dst
            keys = [(k // p, k % p) for k in m.blocks]
            idxs = [slot[src].index(k) for k in keys]
            assert len(idxs) == p // 2
            send_slots[j, src] = idxs
            if sorted(idxs) != list(range(min(idxs), min(idxs) + len(idxs))):
                contig = False
            incoming[dst] = keys
            vacated[src] = idxs
            pairs.append((src, dst))
        perms.append(tuple(pairs))
        for r in range(p):
            iv = vacated[r]
            ik = incoming[r]
            recv_slots[j, r] = iv
            for t, k in zip(iv, ik):
                slot[r][t] = k
    final_slots = np.zeros((p, p), dtype=np.int64)
    for r in range(p):
        for t, (d, o) in enumerate(slot[r]):
            assert d == r
            final_slots[r, o] = t
    return AlltoallTables(p, nsteps, tuple(perms), send_slots, recv_slots,
                          final_slots, contig)
