"""Tree constructions: Bine (paper Sec. 2-3) and classical binomial baselines.

A *tree schedule* for p ranks is a list of steps; step ``i`` is a list of
``(src, dst)`` pairs.  For a broadcast rooted at 0, every rank receives
exactly once, senders already hold the data, and after ``s = log2(p)``
steps all ranks hold it.  Reduce / gather / scatter reuse the same trees
with time reversed.

Every function takes the root as rank 0; roots ``t != 0`` are handled by the
callers with the paper's logical rotation (subtract ``t`` mod p).
"""

from __future__ import annotations

from functools import lru_cache
from typing import List, Tuple

from .negabinary import (
    log2_int,
    nb2rank,
    ones,
    rank2nb,
    trailing_run,
    v_inverse,
    v_table,
)

Step = List[Tuple[int, int]]
Schedule = List[Step]


# ---------------------------------------------------------------------------
# Bine distance-halving tree (paper Sec. 2.3)
# ---------------------------------------------------------------------------

def bine_dh_join_step(r: int, p: int) -> int:
    """Step at which rank r receives in a root-0 distance-halving Bine bcast.

    i = s - u, with u the trailing equal-bit run of rank2nb(r) (Sec. 2.3.2).
    The root never receives; we return -1 for it.
    """
    if r % p == 0:
        return -1
    s = log2_int(p)
    return s - trailing_run(rank2nb(r, p), s)


def bine_dh_peer(r: int, p: int, i: int) -> int:
    """Partner of rank r at step i (Eq. 1): XOR the s-i LSBs of the label."""
    s = log2_int(p)
    return nb2rank(rank2nb(r, p) ^ ones(s - i), p)


@lru_cache(maxsize=None)
def bine_dh_tree(p: int) -> Schedule:
    """Full (src, dst) schedule of the distance-halving Bine broadcast."""
    s = log2_int(p)
    sched: Schedule = []
    has = [r == 0 for r in range(p)]
    for i in range(s):
        step: Step = []
        nxt = list(has)
        for r in range(p):
            if has[r]:
                q = bine_dh_peer(r, p, i)
                step.append((r, q))
                nxt[q] = True
        has = nxt
        sched.append(step)
    assert all(has), f"bine_dh_tree does not cover all ranks for p={p}"
    return sched


# ---------------------------------------------------------------------------
# Bine distance-doubling tree (paper Sec. 3.2)
# ---------------------------------------------------------------------------

def bine_dd_join_step(r: int, p: int) -> int:
    """Rank r receives at the position of the MSB set in v(r) (Sec. 3.2.2)."""
    if r % p == 0:
        return -1
    v = int(v_table(p)[r % p])
    return v.bit_length() - 1


@lru_cache(maxsize=None)
def bine_dd_tree(p: int) -> Schedule:
    """Distance-doubling Bine broadcast: binomial algorithm in v-space.

    At step i, every rank whose v-label has all bits >= i clear sends to the
    rank whose v-label differs in bit i.
    """
    s = log2_int(p)
    vt = v_table(p)
    inv = v_inverse(p)
    sched: Schedule = []
    for i in range(s):
        step: Step = []
        for r in range(p):
            if vt[r] < (1 << i):  # r already has the data (msb(v) < i or root)
                q = int(inv[vt[r] ^ (1 << i)])
                step.append((r, q))
        sched.append(step)
    return sched


# ---------------------------------------------------------------------------
# Classical binomial trees (baselines; Open MPI / MPICH constructions)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def binomial_dd_tree(p: int) -> Schedule:
    """Distance-doubling binomial bcast (Open MPI style, Fig. 1 top).

    Step i: ranks r < 2**i send to r + 2**i.
    """
    s = log2_int(p)
    return [
        [(r, r + (1 << i)) for r in range(min(1 << i, p - (1 << i)))]
        for i in range(s)
    ]


@lru_cache(maxsize=None)
def binomial_dh_tree(p: int) -> Schedule:
    """Distance-halving binomial bcast (MPICH style, Fig. 1 bottom).

    Step i: ranks r with the s-i low bits zero send to r + 2**(s-i-1).
    """
    s = log2_int(p)
    sched: Schedule = []
    for i in range(s):
        d = 1 << (s - i - 1)
        step = [(r, r + d) for r in range(0, p, 2 * d)]
        sched.append(step)
    return sched


TREES = {
    "bine_dh": bine_dh_tree,
    "bine_dd": bine_dd_tree,
    "binomial_dh": binomial_dh_tree,
    "binomial_dd": binomial_dd_tree,
}


def rotate_schedule(sched: Schedule, root: int, p: int) -> Schedule:
    """Re-root a root-0 schedule at ``root`` by rotating rank ids (Sec. 2.2)."""
    if root % p == 0:
        return sched
    return [[((a + root) % p, (b + root) % p) for a, b in step] for step in sched]


def subtree_blocks(sched: Schedule, p: int) -> List[List[int]]:
    """For each rank, the ranks in the subtree it roots (itself + descendants).

    Computed by replaying the schedule backwards: a node's subtree is itself
    plus the subtrees of every rank it sends to after joining.
    """
    children: List[List[int]] = [[] for _ in range(p)]
    for step in sched:
        for src, dst in step:
            children[src].append(dst)

    out: List[List[int]] = [[] for _ in range(p)]

    def collect(r: int) -> List[int]:
        if not out[r]:
            acc = [r]
            for c in children[r]:
                acc.extend(collect(c))
            out[r] = acc
        return out[r]

    for r in range(p):
        collect(r)
    return out
