"""Predicted-vs-measured drift: EWMA residuals per decision-table cell.

The decision tables behind ``backend="auto"`` are only as good as
``topology.cost.predict_time`` — and the model goes stale: a firmware
update changes link bandwidth, a colocated job steals HBM, a preset's β
was fit on another machine.  This module closes the monitoring half of
the tuning loop (the Barchet-Estefanel & Mounié lineage in PAPERS.md):
every measured collective wall time — tuner probe cells and benchmark
timings alike — is compared against the model's prediction *for the same
(collective, backend, p, payload, wire)*, and the log-ratio residual

    r = ln(measured / predicted)

is folded into an EWMA per decision-table cell ``(collective, p,
payload-bucket)``.  Cells whose |EWMA| exceeds the threshold become
**retune hints**: ``launch/tune.py --hints`` probes exactly those cells
instead of the full grid, so a drifted table refreshes in seconds.

Storage follows the ``tuner.store`` pattern to the letter: one JSON file
per ``(device_kind, topology, p)`` under ``REPRO_DRIFT_DIR`` (default
``~/.cache/repro-bine/drift``), atomic writes, caller-supplied timestamps
recorded verbatim, corrupt files quarantined (``.corrupt``) with one
warning per path, unwritable dirs warned once instead of raised.
"""

from __future__ import annotations

import json
import math
import os
import re
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_FORMAT = 1

#: |EWMA log-ratio| above which a cell is considered drifted.  0.405 is
#: ln(1.5): flag when measurement disagrees with the model by ~1.5x in
#: either direction, comfortably past run-to-run timer noise.
DEFAULT_THRESHOLD = math.log(1.5)

#: EWMA smoothing (matches fleet.feedback.EWMA_ALPHA: ~last 10 samples)
EWMA_ALPHA = 0.2

CORRUPT_SUFFIX = ".corrupt"

#: paths already warned about this process (corrupt and unwritable alike)
_WARNED_PATHS: set = set()


def _warn_once(path: str, msg: str) -> None:
    if path in _WARNED_PATHS:
        return
    _WARNED_PATHS.add(path)
    warnings.warn(msg, stacklevel=3)


@dataclass
class DriftCell:
    """EWMA residual state of one decision-table cell."""
    collective: str
    bucket: int                 # SIZE_BUCKETS index of the payload
    ewma_log_ratio: float = 0.0
    n: int = 0
    #: the last sample's concrete dispatch, for the report's provenance
    last_backend: str = ""
    last_wire: str = "float32"
    last_nbytes: int = 0

    def update(self, log_ratio: float, backend: str, wire: str,
               nbytes: int, alpha: float = EWMA_ALPHA) -> None:
        self.n += 1
        if self.n == 1:
            self.ewma_log_ratio = float(log_ratio)
        else:
            self.ewma_log_ratio += alpha * (float(log_ratio)
                                            - self.ewma_log_ratio)
        self.last_backend = backend
        self.last_wire = wire
        self.last_nbytes = int(nbytes)

    def key(self) -> str:
        return f"{self.collective}/b{self.bucket}"


@dataclass
class DriftSet:
    """All drift cells of one ``(device_kind, topology, p)`` store key."""
    device_kind: str
    topology: str
    p: int
    provenance: Dict[str, Optional[str]] = field(default_factory=dict)
    cells: Dict[str, DriftCell] = field(default_factory=dict)

    def key(self) -> str:
        return f"{_slug(self.device_kind)}__{_slug(self.topology)}__p{self.p}"

    def cell(self, collective: str, bucket: int) -> DriftCell:
        k = f"{collective}/b{bucket}"
        c = self.cells.get(k)
        if c is None:
            c = self.cells[k] = DriftCell(collective=collective,
                                          bucket=bucket)
        return c

    def to_json_dict(self) -> dict:
        return {
            "format": _FORMAT,
            "device_kind": self.device_kind,
            "topology": self.topology,
            "p": self.p,
            "provenance": dict(self.provenance),
            "cells": {
                k: {"collective": c.collective, "bucket": c.bucket,
                    "ewma_log_ratio": c.ewma_log_ratio, "n": c.n,
                    "last_backend": c.last_backend,
                    "last_wire": c.last_wire,
                    "last_nbytes": c.last_nbytes}
                for k, c in sorted(self.cells.items())
            },
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> "DriftSet":
        if not isinstance(d, dict) or d.get("format") != _FORMAT:
            raise ValueError(
                f"unsupported drift format "
                f"{d.get('format') if isinstance(d, dict) else type(d)!r}")
        out = cls(device_kind=d["device_kind"], topology=d["topology"],
                  p=int(d["p"]), provenance=dict(d.get("provenance", {})))
        for k, c in d.get("cells", {}).items():
            out.cells[k] = DriftCell(
                collective=c["collective"], bucket=int(c["bucket"]),
                ewma_log_ratio=float(c.get("ewma_log_ratio", 0.0)),
                n=int(c.get("n", 0)),
                last_backend=c.get("last_backend", ""),
                last_wire=c.get("last_wire", "float32"),
                last_nbytes=int(c.get("last_nbytes", 0)))
        return out


# ---------------------------------------------------------------------------
# Residual accounting
# ---------------------------------------------------------------------------

def predicted_time(collective: str, backend: str, p: int, nbytes: int,
                   topology: str, wire_dtype: str = "float32"
                   ) -> Optional[float]:
    """Model time for one measured dispatch, or None where the cost
    engine has no entry (an unpriceable backend never drifts a cell)."""
    from repro.topology.cost import predict_time
    from repro.topology.presets import get_topology
    try:
        topo = get_topology(topology, p)
        return predict_time(collective, backend, p, float(nbytes), topo,
                            wire_dtype=wire_dtype)
    except (KeyError, ValueError):
        return None


def payload_bucket(nbytes: int) -> int:
    """Decision-table size-bucket index of a payload — the drift cell's
    key axis, shared with ``topology.table.DecisionTable.bucket_of``."""
    from repro.topology.table import SIZE_BUCKETS
    import bisect
    return min(bisect.bisect_left(SIZE_BUCKETS, nbytes),
               len(SIZE_BUCKETS) - 1)


def bucket_bytes(bucket: int) -> int:
    """Representative payload (the inclusive upper edge) of one bucket —
    what ``--hints`` re-probes the cell at."""
    from repro.topology.table import SIZE_BUCKETS
    return int(SIZE_BUCKETS[bucket])


def observe(dset: DriftSet, collective: str, backend: str, nbytes: int,
            measured_s: float, wire_dtype: str = "float32",
            alpha: float = EWMA_ALPHA) -> Optional[float]:
    """Fold one measured wall time into its drift cell.

    Returns the sample's log-ratio, or None when the model cannot price
    the dispatch or the measurement is degenerate (non-positive).
    """
    if measured_s <= 0.0:
        return None
    pred = predicted_time(collective, backend, dset.p, nbytes,
                          dset.topology, wire_dtype)
    if pred is None or pred <= 0.0:
        return None
    lr = math.log(measured_s / pred)
    dset.cell(collective, payload_bucket(nbytes)).update(
        lr, backend, wire_dtype, nbytes, alpha=alpha)
    return lr


def ingest_measurements(ms, topology: Optional[str] = None,
                        base: Optional[DriftSet] = None) -> DriftSet:
    """Fold a ``tuner.store.MeasurementSet`` into a drift set — probe
    measurements double as drift samples, so every ``launch/tune.py`` run
    refreshes the residuals for free.  ``base`` continues an existing
    set (the load-update-save cycle); otherwise a fresh one is built."""
    dset = base if base is not None else DriftSet(
        device_kind=ms.device_kind, topology=topology or ms.topology,
        p=ms.p, provenance=dict(ms.provenance))
    for m in ms.measurements:
        observe(dset, m.collective, m.backend, m.nbytes, m.time_s,
                wire_dtype=m.wire_dtype)
    return dset


@dataclass(frozen=True)
class RetuneHint:
    """One drifted cell: what to re-probe, and why."""
    collective: str
    p: int
    bucket: int
    nbytes: int                 # representative payload for the re-probe
    ewma_log_ratio: float
    n: int
    last_backend: str

    @property
    def ratio(self) -> float:
        """measured/predicted as a plain factor (e^EWMA)."""
        return math.exp(self.ewma_log_ratio)


def hints(dset: DriftSet,
          threshold: float = DEFAULT_THRESHOLD) -> List[RetuneHint]:
    """Cells whose |EWMA log-ratio| exceeds ``threshold``, worst first."""
    out = []
    for c in dset.cells.values():
        if c.n > 0 and abs(c.ewma_log_ratio) > threshold:
            out.append(RetuneHint(
                collective=c.collective, p=dset.p, bucket=c.bucket,
                nbytes=c.last_nbytes or bucket_bytes(c.bucket),
                ewma_log_ratio=c.ewma_log_ratio, n=c.n,
                last_backend=c.last_backend))
    return sorted(out, key=lambda h: -abs(h.ewma_log_ratio))


# ---------------------------------------------------------------------------
# Store (the tuner.store layout)
# ---------------------------------------------------------------------------

def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "-", s).strip("-") or "unknown"


def drift_dir() -> str:
    env = os.environ.get("REPRO_DRIFT_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-bine",
                        "drift")


def drift_path(dset: DriftSet, dir: Optional[str] = None) -> str:
    return os.path.join(dir or drift_dir(), dset.key() + ".json")


def save_drift(dset: DriftSet, dir: Optional[str] = None) -> Optional[str]:
    """Write (atomically) one drift set; returns the path, or None with
    one warning when the directory is unwritable (a read-only cache must
    degrade the monitoring, never kill the run that produced the data)."""
    path = drift_path(dset, dir)
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dset.to_json_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except OSError as e:
        _warn_once(path, f"drift store {path} is unwritable ({e!r}); "
                         f"residuals from this run are NOT persisted")
        return None
    return path


def load_drift(device_kind: str, topology: str, p: int,
               dir: Optional[str] = None) -> Optional[DriftSet]:
    """One key's persisted drift set, or None — never raises.  Corrupt
    files are quarantined with one warning (the ``tuner.store`` contract)."""
    path = os.path.join(
        dir or drift_dir(),
        f"{_slug(device_kind)}__{_slug(topology)}__p{p}.json")
    try:
        with open(path) as f:
            return DriftSet.from_json_dict(json.load(f))
    except FileNotFoundError:
        return None
    except (OSError, ValueError, KeyError, TypeError,
            json.JSONDecodeError) as e:
        _warn_once(path, f"drift store file {path} is unreadable ({e!r}); "
                         f"quarantined to {path + CORRUPT_SUFFIX}")
        try:
            os.replace(path, path + CORRUPT_SUFFIX)
        except OSError:
            pass
        return None


def load_all_drift(topology: Optional[str] = None,
                   dir: Optional[str] = None,
                   device_kind: Optional[str] = None) -> List[DriftSet]:
    """Every persisted drift set (optionally filtered), file-name order."""
    d = dir or drift_dir()
    if not os.path.isdir(d):
        return []
    out = []
    for fname in sorted(os.listdir(d)):
        if not fname.endswith(".json"):
            continue
        try:
            with open(os.path.join(d, fname)) as f:
                dset = DriftSet.from_json_dict(json.load(f))
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError):
            continue
        if topology is not None and dset.topology != topology:
            continue
        if device_kind is not None and dset.device_kind != device_kind:
            continue
        out.append(dset)
    return out
