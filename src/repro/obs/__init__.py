"""Run-wide observability: metrics registry, link-byte attribution,
predicted-vs-measured drift, and trace-timeline export.

See README "Observability" for the lifecycle; the pieces are:

  * :mod:`repro.obs.metrics`  — counters/gauges/quantile histograms;
  * :mod:`repro.obs.collect`  — per-dispatch link-byte attribution;
  * :mod:`repro.obs.drift`    — EWMA residuals + retune hints;
  * :mod:`repro.obs.timeline` — Chrome-trace/Perfetto + Prometheus text.
"""

from repro.obs.metrics import (  # noqa: F401
    Registry,
    disabled,
    dump_registry,
    enabled,
    get_registry,
    scope,
    set_enabled,
)
from repro.obs.timeline import (  # noqa: F401
    Timeline,
    dump_chrome_trace,
    export_prom,
    get_timeline,
    to_chrome_trace,
)
