"""Trace-timeline export (Chrome trace / Perfetto) + Prometheus text.

The runtime layers append events into a :class:`Timeline` — train steps
and fleet ticks as **spans**, chaos faults and replica drain/respawn as
**instants** — and :func:`to_chrome_trace` renders them in the Chrome
Trace Event format (``{"traceEvents": [...]}``, ``ph="X"`` complete
spans and ``ph="i"`` instants, microsecond timestamps), which loads
directly in ``ui.perfetto.dev`` or ``chrome://tracing``.

Two time bases coexist by design:

  * **train** events are wall-clock (``time.time()`` seconds at the call
    site, rendered as µs since the timeline's first event);
  * **fleet/serve** events use the fleet's *virtual integer tick clock*
    (1 tick = 1 µs in the trace) — deterministic replays produce
    byte-identical timelines, and chaos instants land exactly on the
    tick that armed them.

Each producer gets its own ``pid`` lane ("train", "fleet", …) so the two
clocks never share a track and the viewer shows them as separate
processes.

:func:`export_prom` renders a :class:`~repro.obs.metrics.Registry` in
the Prometheus text exposition format (counters/gauges as samples,
histograms as ``_count``/``_sum`` + quantile gauges) for anyone who
wants to scrape a run artifact into existing dashboards.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.obs import metrics

#: pid lanes in the trace, one per producer clock
LANES = ("train", "fleet", "serve", "chaos", "bench")


@dataclass
class Event:
    """One timeline event; ``dur_us`` None means an instant (``ph="i"``)."""
    name: str
    lane: str                   # pid lane / which clock the ts is on
    ts_us: float
    dur_us: Optional[float] = None
    args: Dict = field(default_factory=dict)
    track: str = "0"            # tid within the lane (replica id, …)


class Timeline:
    """Append-only event log for one run."""

    def __init__(self):
        self.events: List[Event] = []

    def span(self, name: str, lane: str, ts_us: float, dur_us: float,
             track: str = "0", **args) -> None:
        if not metrics.enabled():
            return
        self.events.append(Event(name=name, lane=lane, ts_us=float(ts_us),
                                 dur_us=float(dur_us), track=str(track),
                                 args=dict(args)))

    def instant(self, name: str, lane: str, ts_us: float,
                track: str = "0", **args) -> None:
        if not metrics.enabled():
            return
        self.events.append(Event(name=name, lane=lane, ts_us=float(ts_us),
                                 track=str(track), args=dict(args)))

    def __len__(self) -> int:
        return len(self.events)

    def clear(self) -> None:
        self.events.clear()

    # -- serialization --------------------------------------------------------

    def to_json_dict(self) -> List[dict]:
        return [{"name": e.name, "lane": e.lane, "ts_us": e.ts_us,
                 "dur_us": e.dur_us, "track": e.track, "args": e.args}
                for e in self.events]

    @classmethod
    def from_json_dict(cls, rows: List[dict]) -> "Timeline":
        tl = cls()
        for r in rows:
            tl.events.append(Event(
                name=r["name"], lane=r["lane"], ts_us=float(r["ts_us"]),
                dur_us=None if r.get("dur_us") is None else float(r["dur_us"]),
                track=str(r.get("track", "0")), args=dict(r.get("args", {}))))
        return tl


def to_chrome_trace(tl: Timeline) -> dict:
    """Render as a Chrome Trace Event JSON object.

    Wall-clock lanes are rebased so the run's first event sits at ts=0
    (Perfetto dislikes epoch-scale microsecond offsets); virtual-tick
    lanes are already small integers and pass through unchanged.
    """
    # rebase each lane independently: lanes are separate clocks
    base: Dict[str, float] = {}
    for e in tl.events:
        if e.ts_us >= 1e12:  # epoch-scale wall clock
            base[e.lane] = min(base.get(e.lane, e.ts_us), e.ts_us)
    trace: List[dict] = []
    pids = {lane: i + 1 for i, lane in enumerate(LANES)}
    for e in tl.events:
        pid = pids.setdefault(e.lane, len(pids) + 1)
        row = {"name": e.name, "pid": pid, "tid": e.track,
               "ts": e.ts_us - base.get(e.lane, 0.0), "args": e.args}
        if e.dur_us is None:
            row["ph"] = "i"
            row["s"] = "p"      # process-scoped instant marker
        else:
            row["ph"] = "X"
            row["dur"] = e.dur_us
        trace.append(row)
    meta = [{"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": lane}} for lane, pid in sorted(
                 pids.items(), key=lambda kv: kv[1])]
    return {"traceEvents": meta + trace, "displayTimeUnit": "ms"}


def dump_chrome_trace(tl: Timeline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tl), f, indent=1, sort_keys=True)
        f.write("\n")


#: the default timeline the instrumented layers append to
_TIMELINE = Timeline()


def get_timeline() -> Timeline:
    return _TIMELINE


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _esc(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def _prom_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _prom_num(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def export_prom(reg: Optional[metrics.Registry] = None) -> str:
    """Prometheus text exposition of a registry (default: the process
    registry).  Counters render with the ``_total`` suffix convention;
    histograms as ``_count``/``_sum`` plus p50/p99 quantile samples."""
    reg = reg or metrics.get_registry()
    lines: List[str] = []
    seen_types = set()

    def typeline(name: str, kind: str):
        if name not in seen_types:
            seen_types.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for (name, lk), v in sorted(reg.counters.items()):
        typeline(f"{name}_total", "counter")
        lines.append(f"{name}_total{_prom_labels(dict(lk))} {_prom_num(v)}")
    for (name, lk), v in sorted(reg.gauges.items()):
        typeline(name, "gauge")
        lines.append(f"{name}{_prom_labels(dict(lk))} {_prom_num(v)}")
    for (name, lk), h in sorted(reg.histograms.items()):
        typeline(name, "summary")
        labels = dict(lk)
        for q in (0.5, 0.99):
            qlabels = dict(labels, quantile=str(q))
            lines.append(f"{name}{_prom_labels(qlabels)} "
                         f"{_prom_num(h.quantile(q * 100.0))}")
        lines.append(f"{name}_count{_prom_labels(labels)} {h.count}")
        lines.append(f"{name}_sum{_prom_labels(labels)} {_prom_num(h.total)}")
    return "\n".join(lines) + ("\n" if lines else "")
