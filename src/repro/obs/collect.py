"""Collective-call telemetry: per-dispatch counters + link-byte attribution.

Every dispatch through ``collectives.api`` (and every train wire bucket)
records ``(collective, algo, backend, wire_dtype, payload_bytes, p)``
into the metrics registry, plus the schedule-derived local/global link
bytes that dispatch will put on the wire — the paper's headline metric,
live in every run instead of only in the offline tracer.

The attribution reuses the ``tuner.trace`` schedule replay, but cached as
*block counts*: for one ``(collective, algo, p, topology)`` the replay
runs once with ``vec_bytes = p`` so every per-message size is exactly its
integer block count, and the summed (local, global) block totals are
cached.  ``msg_bytes`` is linear in ``vec_bytes``, so for any payload::

    recorded_bytes = blocks * payload_bytes / p

which equals ``core.traffic.global_bytes(sched, p, payload, topo)``
EXACTLY for power-of-two payloads and rank counts (every term is an exact
binary float — the invariant tests/obs/test_collect.py locks against the
closed form for every registered (collective, algo) pair).

Cost discipline: all of this runs at **jit trace time** — the
``collectives.api`` functions only execute while a shard_map body is
being traced, shapes and axis sizes are static Python ints, and the cache
makes repeat dispatches a dict lookup.  Nothing here ever touches a
traced value or syncs a device, so instrumentation cannot add retraces
or steady-state cost (the serve-throughput benchmark gates both).
"""

from __future__ import annotations

import warnings
from functools import lru_cache
from typing import Optional, Tuple

from repro.obs import metrics

#: attribution failures already warned about (one per signature per process)
_WARNED_KEYS: set = set()


@lru_cache(maxsize=4096)
def link_blocks(collective: str, algo: str, p: int, topology: str,
                root: int = 0,
                placement: Optional[Tuple[int, ...]] = None
                ) -> Tuple[int, int]:
    """(local, global) summed link *block counts* of one schedule replay.

    Replays ``get_schedule(collective, algo, p, root)`` on the preset's
    topology with ``vec_bytes = p`` (so each message weighs exactly its
    ``nblocks``) and returns the integer step totals.  Torus presets route
    dimension-ordered; their hop blocks land in the local slot and the
    global slot is 0 (a torus has no group boundary to cross).

    ``placement`` (rank -> node, a tuple so the cache can key it) defaults
    to identity — the runtime layers don't know the scheduler's node map;
    tests use it to spread ranks across groups.
    """
    from repro.topology.presets import get_topology
    from repro.tuner.trace import trace_collective

    topo = get_topology(topology, p)
    res = trace_collective(collective, algo, p, float(p), topo,
                           placement=placement, root=root)
    return int(round(res.local_bytes)), int(round(res.global_bytes))


def attributed_bytes(collective: str, algo: str, p: int,
                     payload_bytes: float, topology: str, root: int = 0,
                     placement: Optional[Tuple[int, ...]] = None
                     ) -> Tuple[float, float]:
    """(local, global) bytes this dispatch puts on the links.

    Exact equality with ``core.traffic.global_bytes`` for pow2
    ``payload_bytes``/``p``: the cached block totals are integers and the
    per-payload scaling ``blocks * payload / p`` distributes exactly over
    the replay's per-message sum.
    """
    loc, glo = link_blocks(collective, algo, p, topology, root, placement)
    return loc * float(payload_bytes) / p, glo * float(payload_bytes) / p


def _warn_attr_once(key: Tuple, err: BaseException) -> None:
    if key in _WARNED_KEYS:
        return
    _WARNED_KEYS.add(key)
    warnings.warn(
        f"obs: no link-byte attribution for {key} ({err!r}); the dispatch "
        f"counters still record, only the byte breakdown is skipped",
        stacklevel=3)


def record(collective: str, backend: str, p: int, payload_bytes: int,
           wire_dtype: str = "float32", topology: str = "tpu_multipod",
           small_cutoff_bytes: int = 16384, root: int = 0,
           source: str = "api") -> None:
    """Record one collective dispatch into the default registry.

    Emits, all labeled ``(collective, algo, backend, wire_dtype,
    topology, p, source)``:

      * ``collective_calls``          — dispatch count;
      * ``collective_payload_bytes``  — Σ full-vector payload;
      * ``link_local_bytes`` / ``link_global_bytes`` — schedule-replayed
        byte attribution (wire-dtype scaling applied to what actually
        crosses the links).

    Attribution maps the API backend to its schedule via
    ``topology.cost.schedule_algo`` (small/large switch, xla proxies,
    bine_hier composition included); backends it cannot price keep their
    call counters and warn once.
    """
    if not metrics.enabled():
        return
    reg = metrics.get_registry()
    from repro.topology.cost import schedule_algo

    try:
        sched_coll, algo = schedule_algo(collective, backend, payload_bytes,
                                         small_cutoff_bytes)
    except (KeyError, ValueError) as e:
        _warn_attr_once((collective, backend), e)
        sched_coll = algo = None

    labels = dict(collective=collective, backend=backend,
                  algo=algo or "unknown", wire_dtype=wire_dtype,
                  topology=topology, p=p, source=source)
    reg.inc("collective_calls", 1.0, **labels)
    reg.inc("collective_payload_bytes", float(payload_bytes), **labels)
    if algo is None:
        return
    try:
        loc, glo = attributed_bytes(sched_coll, algo, p,
                                    float(payload_bytes), topology, root)
    except Exception as e:  # unknown preset / non-executable p: count only
        _warn_attr_once((sched_coll, algo, p, topology), e)
        return
    # the wire codec shrinks what actually crosses the links; the payload
    # counter above stays the full-vector f32 convention
    scale = _wire_scale(wire_dtype)
    reg.inc("link_local_bytes", loc * scale, **labels)
    reg.inc("link_global_bytes", glo * scale, **labels)


def _wire_scale(wire_dtype: str) -> float:
    if wire_dtype == "float32":
        return 1.0
    try:
        from repro.collectives.compression import wire_factor
        return wire_factor(wire_dtype)
    except Exception:
        return 1.0


def record_api(cfg, collective: str, p: int, nbytes: int,
               root: int = 0) -> None:
    """The ``collectives.api`` hook: one resolved dispatch.

    ``cfg`` is the post-``_resolve`` CollectiveConfig (concrete backend
    and wire, never "auto").  Called with static trace-time ints only.
    """
    if not metrics.enabled():
        return
    record(collective, cfg.backend, p, nbytes, wire_dtype=cfg.wire_dtype,
           topology=cfg.topology, small_cutoff_bytes=cfg.small_cutoff_bytes,
           root=root, source="api")


def record_bucket_plan(tcfg, plan, decisions, n_dp: int) -> None:
    """The ``train.step`` hook: the step's static per-bucket decisions.

    One reduce-scatter and one allgather record per wire bucket, at the
    exact payloads and resolved ``(backend, wire)`` the compiled step
    dispatches — recorded once at build time (the decisions are static),
    which is precisely once per compilation of the step.
    """
    if not metrics.enabled() or plan is None or decisions is None:
        return
    import numpy as np
    for b, (rs_b, rs_w, ag_b, ag_w) in zip(plan.buckets, decisions):
        rs_bytes = int(b.nbytes(plan.wire_itemsize, n_dp))
        ag_bytes = int(b.nbytes(np.dtype(b.dtype).itemsize, n_dp))
        record("reduce_scatter", rs_b, n_dp, rs_bytes, wire_dtype=rs_w,
               topology=tcfg.topology,
               small_cutoff_bytes=tcfg.small_cutoff_bytes,
               source="train_bucket")
        record("allgather", ag_b, n_dp, ag_bytes, wire_dtype=ag_w,
               topology=tcfg.topology,
               small_cutoff_bytes=tcfg.small_cutoff_bytes,
               source="train_bucket")


def record_serve_plan(rows, topology: str,
                      small_cutoff_bytes: int = 16384) -> None:
    """The ``serve.engine`` hook: the decode plan's per-step collectives.

    Decode runs in GSPMD auto mode, so the plan is advisory — these rows
    are the per-decode-step collectives the cost model priced when it
    chose each backend, recorded once at ``make_serve_fns`` build time
    (``source="serve_plan"``).  ``rows`` is an iterable of
    ``(collective, backend, p, nbytes)``.
    """
    if not metrics.enabled():
        return
    for collective, backend, p, nbytes in rows:
        record(collective, backend, p, int(nbytes),
               topology=topology, small_cutoff_bytes=small_cutoff_bytes,
               source="serve_plan")


def global_local_summary(reg: Optional[metrics.Registry] = None) -> dict:
    """Per-(backend, topology) global/local byte totals — the report
    CLI's "is the locality story holding" table."""
    reg = reg or metrics.get_registry()
    out: dict = {}
    for name in ("link_global_bytes", "link_local_bytes"):
        for labels, value in reg.series(name):
            key = (labels.get("backend", "?"), labels.get("topology", "?"))
            row = out.setdefault(key, {"global": 0.0, "local": 0.0})
            row["global" if name == "link_global_bytes" else "local"] += value
    return out
