"""Process-local metrics registry: counters, gauges, quantile histograms.

Zero dependencies beyond the stdlib, no background threads, no sockets —
the registry is a plain in-process accumulator the runtime layers write
into and the report/export paths read out of.  Three instrument kinds:

  * **counter** — monotonically accumulated float (``inc``);
  * **gauge**   — last-write-wins float (``set_gauge``);
  * **histogram** — raw observations, summarized by *nearest-rank*
    quantiles (the ``serve.scheduler._pct`` convention: deterministic,
    no interpolation) so registry percentiles agree digit-for-digit with
    the scheduler's own latency summaries.

Every sample carries a label set.  Labels come from the call site plus
whatever :func:`scope` frames are active::

    with REGISTRY.scope(replica="0"):
        REGISTRY.inc("fleet_ticks")           # labeled {replica="0"}

Series identity is ``(name, sorted labels)`` — the Prometheus data-model
convention — so ``export_prom`` (``repro.obs.timeline``) can render the
registry losslessly.

The module-level default registry (:func:`get_registry`) is what the
instrumented layers (``collectives.api``, ``fleet``, ``train.runtime``,
…) write to; :func:`enabled` / :func:`set_enabled` gate all of them at
once (env ``REPRO_OBS=0`` starts a process disabled), which is how the
serve-throughput benchmark measures the instrumentation's own overhead.
"""

from __future__ import annotations

import json
import math
import os
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

#: a series key: (metric name, ((label, value), ...) sorted by label)
SeriesKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def _nearest_rank(xs: List[float], q: float) -> float:
    """Nearest-rank percentile of ``xs`` at ``q`` in [0, 100] — identical
    to ``serve.scheduler._pct`` (duplicated so obs stays import-light)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = max(0, min(len(xs) - 1, int(math.ceil(q / 100.0 * len(xs))) - 1))
    return float(xs[k])


def _labels_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclass
class Histogram:
    """Raw-sample histogram with nearest-rank quantiles.

    Samples are kept verbatim (runs here are bounded — fleet ticks, train
    steps, probe cells), so any quantile is exact; ``summary`` renders
    the fixed p50/p99 pair every latency report in this repo uses.
    """
    samples: List[float] = field(default_factory=list)

    def observe(self, x: float) -> None:
        self.samples.append(float(x))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def total(self) -> float:
        return float(sum(self.samples))

    def quantile(self, q: float) -> float:
        return _nearest_rank(self.samples, q)

    def summary(self) -> Dict[str, float]:
        return {"count": float(self.count), "sum": self.total,
                "p50": self.quantile(50.0), "p99": self.quantile(99.0)}


class Registry:
    """One process-local metrics store (counters + gauges + histograms)."""

    def __init__(self):
        self.counters: Dict[SeriesKey, float] = {}
        self.gauges: Dict[SeriesKey, float] = {}
        self.histograms: Dict[SeriesKey, Histogram] = {}
        self._scope_stack: List[Dict[str, str]] = []

    # -- labels --------------------------------------------------------------

    @contextmanager
    def scope(self, **labels) -> Iterator[None]:
        """Label frame: every sample recorded inside carries ``labels``
        (inner frames and call-site labels win on key collisions)."""
        self._scope_stack.append({str(k): str(v) for k, v in labels.items()})
        try:
            yield
        finally:
            self._scope_stack.pop()

    def _key(self, name: str, labels: Dict) -> SeriesKey:
        merged: Dict[str, str] = {}
        for frame in self._scope_stack:
            merged.update(frame)
        merged.update({str(k): str(v) for k, v in labels.items()})
        return (name, _labels_key(merged))

    # -- instruments ---------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, **labels) -> float:
        """Add ``value`` to a counter; returns the new total."""
        key = self._key(name, labels)
        self.counters[key] = self.counters.get(key, 0.0) + float(value)
        return self.counters[key]

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self.gauges[self._key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one histogram sample."""
        key = self._key(name, labels)
        hist = self.histograms.get(key)
        if hist is None:
            hist = self.histograms[key] = Histogram()
        hist.observe(value)

    # -- reads ---------------------------------------------------------------

    def counter_value(self, name: str, **labels) -> float:
        return self.counters.get(self._key(name, labels), 0.0)

    def gauge_value(self, name: str, **labels) -> Optional[float]:
        return self.gauges.get(self._key(name, labels))

    def quantile(self, name: str, q: float, **labels) -> float:
        hist = self.histograms.get(self._key(name, labels))
        return hist.quantile(q) if hist is not None else 0.0

    def series(self, name: str) -> List[Tuple[Dict[str, str], float]]:
        """Every (labels, value) of one counter/gauge name, sorted by
        label set — the report CLI's aggregation input."""
        out = []
        for store in (self.counters, self.gauges):
            for (n, lk), v in store.items():
                if n == name:
                    out.append((dict(lk), v))
        return sorted(out, key=lambda t: sorted(t[0].items()))

    # -- lifecycle / serialization -------------------------------------------

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()

    def snapshot(self) -> dict:
        """JSON-able dump: the run-artifact payload ``launch/report.py``
        renders.  Histograms serialize as summaries plus raw samples, so
        a loaded snapshot can still answer any quantile."""
        def rows(store):
            return [{"name": n, "labels": dict(lk), "value": v}
                    for (n, lk), v in sorted(store.items())]
        return {
            "counters": rows(self.counters),
            "gauges": rows(self.gauges),
            "histograms": [
                {"name": n, "labels": dict(lk), **h.summary(),
                 "samples": list(h.samples)}
                for (n, lk), h in sorted(self.histograms.items())
            ],
        }

    @classmethod
    def from_snapshot(cls, d: dict) -> "Registry":
        reg = cls()
        for row in d.get("counters", ()):
            reg.counters[(row["name"], _labels_key(row["labels"]))] = \
                float(row["value"])
        for row in d.get("gauges", ()):
            reg.gauges[(row["name"], _labels_key(row["labels"]))] = \
                float(row["value"])
        for row in d.get("histograms", ()):
            hist = Histogram(samples=[float(x) for x in row["samples"]])
            reg.histograms[(row["name"], _labels_key(row["labels"]))] = hist
        return reg


#: the default registry every instrumented layer writes to
_REGISTRY = Registry()

#: master switch; env REPRO_OBS=0 starts the process disabled
_ENABLED = os.environ.get("REPRO_OBS", "1") not in ("0", "false", "off")


def get_registry() -> Registry:
    return _REGISTRY


def enabled() -> bool:
    return _ENABLED


def set_enabled(on: bool) -> bool:
    """Flip the instrumentation master switch; returns the previous
    state (so benchmark A/B runs can restore it)."""
    global _ENABLED
    prev, _ENABLED = _ENABLED, bool(on)
    return prev


@contextmanager
def disabled() -> Iterator[None]:
    """Temporarily switch instrumentation off (the benchmark's obs-off
    arm and tests that must not pollute the default registry)."""
    prev = set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


def scope(**labels):
    """``get_registry().scope(...)`` — the label mechanism, module-level."""
    return _REGISTRY.scope(**labels)


def dump_registry(path: str, timestamp: Optional[str] = None) -> None:
    """Write the default registry's snapshot as JSON (timestamp recorded
    verbatim — the repo-wide caller-supplies-the-clock convention)."""
    with open(path, "w") as f:
        json.dump({"format": 1, "timestamp": timestamp,
                   "registry": _REGISTRY.snapshot()}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
