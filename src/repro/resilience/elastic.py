"""Survivor-set rescheduling: rebuild the collective/ZeRO plan at p' = p - k.

When ``k`` DP ranks die permanently (the ``rank_loss`` fault kind), the
job does not fall back to flat-ring-or-nothing: this module re-derives
every plan the training step depends on for the survivor count:

  * **Collective schedules** — the schedule IR's non-pow2 adapters
    (fold / 3-2 elimination in ``core.schedules``) produce oracle-
    conformant bine/recdoub schedules at ANY p', so planning, pricing,
    and traffic accounting keep working on the degraded set
    (tests/resilience/test_successive_degradation.py).  *Execution* is a
    stricter contract: ``shmap.run_schedule`` runs full-permutation
    ppermute steps only, so a non-pow2 survivor count executes through
    the ``ring``/``xla`` backends (``collectives.api.executable_at``) —
    :func:`elastic_backend` picks the requested backend wherever it still
    executes and the bandwidth-optimal ring where it does not.
  * **Tier stacks** — re-derived from the topology preset over the
    degraded occupancy via ``topology.tier_split_or_none`` (a survivor
    count that no longer fills its groups gets the split the preset
    actually induces on p', not the stale p-rank stack).
  * **ZeRO bucket rows** — ``replan_buckets`` recomputes the zero layout
    and repacks the gradient buckets at ``n_dp = p'`` (row ownership is
    per-rank, so the p-rank plan is meaningless to the survivors).
  * **Decision tables** — the per-process table cache is invalidated
    (``topology.invalidate_tables``) so backend="auto" re-prices at p'
    instead of serving p-rank selections.

Resuming from the last checkpoint under the replanned step is then
bit-identical to a fresh p'-rank run restored from the same checkpoint
(tests/resilience/test_elastic_resume.py): checkpoints hold *global*
arrays, and every replanned collective is deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


def survivor_set(p: int, lost: Sequence[int]) -> Tuple[int, ...]:
    """The ranks that remain after losing ``lost`` out of ``range(p)``."""
    if p < 1:
        raise ValueError(f"need p >= 1 ranks, got {p}")
    dead = set()
    for r in lost:
        if not 0 <= r < p:
            raise ValueError(f"lost rank {r} outside range(0, {p})")
        if r in dead:
            raise ValueError(f"lost rank {r} listed twice")
        dead.add(r)
    out = tuple(r for r in range(p) if r not in dead)
    if not out:
        raise ValueError(f"losing all {p} ranks leaves no survivor set")
    return out


def elastic_backend(requested: str, p_new: int) -> str:
    """The backend the survivor set actually executes.

    Keeps ``requested`` wherever it still executes at ``p_new``
    (``collectives.api.executable_at``); otherwise falls back to
    ``"ring"`` — runs at any rank count, bandwidth-optimal, and
    deterministic (the bit-identical-resume contract needs a
    deterministic reduction order, which rules out ``"xla"`` as the
    automatic fallback).
    """
    from repro.collectives.api import executable_at
    if executable_at(requested, p_new):
        return requested
    return "ring"


@dataclass(frozen=True)
class SurvivorPlan:
    """Everything re-derived for the survivor set, in one place."""
    p_old: int
    p_new: int
    lost: Tuple[int, ...]
    survivors: Tuple[int, ...]
    #: what the job was configured with
    requested_backend: str
    #: what the survivors execute (== requested wherever still executable)
    backend: str
    topology: str
    #: tier stack over the degraded occupancy (None: no grouped hierarchy,
    #: e.g. the torus)
    tiers: Optional[Tuple[int, ...]]

    @property
    def degraded(self) -> bool:
        return self.p_new != self.p_old

    @property
    def fell_back(self) -> bool:
        return self.backend != self.requested_backend

    def schedule(self, collective: str, algo: Optional[str] = None):
        """The oracle-conformant IR schedule at ``p_new`` — the non-pow2
        adapters kick in automatically for a degraded count.  ``algo``
        defaults to this plan's backend's schedule family."""
        from repro.core.schedules import get_schedule
        return get_schedule(collective, algo or _schedule_family(self.backend),
                            self.p_new)

    def describe(self) -> dict:
        return {
            "p_old": self.p_old, "p_new": self.p_new,
            "lost": list(self.lost),
            "requested_backend": self.requested_backend,
            "backend": self.backend, "fell_back": self.fell_back,
            "topology": self.topology,
            "tiers": None if self.tiers is None else list(self.tiers),
        }


def _schedule_family(backend: str) -> str:
    """API backend name -> ``core.schedules`` algorithm family."""
    if backend.startswith("bine") or backend == "pallas_fused":
        return "bine"
    if backend == "recdoub":
        return "recdoub"
    return "ring"   # ring itself; xla is priced by its ring proxy


def plan_survivors(p: int, lost: Sequence[int], backend: str = "bine",
                   topology: str = "tpu_multipod") -> SurvivorPlan:
    """Build the survivor-set plan for losing ``lost`` ranks out of ``p``.

    Invalidates the per-process decision-table cache as a side effect so
    backend="auto" call sites re-price at the new rank count (stale
    p-rank tables must not outlive the reschedule).
    """
    survivors = survivor_set(p, lost)
    p_new = len(survivors)
    from repro.topology import invalidate_tables, tier_split_or_none
    tiers = tier_split_or_none(topology, p_new)
    invalidate_tables(topology)
    return SurvivorPlan(
        p_old=p, p_new=p_new, lost=tuple(sorted(lost)), survivors=survivors,
        requested_backend=backend, backend=elastic_backend(backend, p_new),
        topology=topology, tiers=tiers)


def replan_buckets(model_cfg, params_shapes, n_dp: int, capacity_bytes: int,
                   wire_itemsize: float = 4.0):
    """Re-derive (zero layout, bucket plan) for the survivor count.

    Bucket rows are per-rank slices, so the old plan's packing is
    meaningless at p': the layout is recomputed (a dim divisible by the
    OLD n_dp may not divide by the new one — such leaves fall back to the
    replicated group) and the buckets repacked over it.  Deterministic:
    same (shapes, n_dp, capacity) -> the identical plan on every host.
    """
    from repro.train import buckets, zero
    layout = zero.zero_layout(model_cfg, params_shapes, n_dp)
    plan = buckets.plan_buckets(params_shapes, layout, n_dp,
                                capacity_bytes, wire_itemsize)
    return layout, plan


def elastic_train_config(tcfg, p_new: int):
    """A :class:`~repro.train.step.TrainConfig` the survivor set can run.

    Swaps in the executable backend for ``p_new`` and drops wire codecs
    that are butterfly-only (int8 / the joint-auto wire) to float32 at a
    non-power-of-two survivor count — a bfloat16 wire is a plain cast and
    survives on any backend.  At a still-pow2 ``p_new`` the config comes
    back unchanged.
    """
    backend = elastic_backend(tcfg.backend, p_new)
    kw = {}
    if backend != tcfg.backend:
        kw["backend"] = backend
    if p_new & (p_new - 1) and tcfg.wire_dtype in ("int8", "auto"):
        kw["wire_dtype"] = "float32"
    return tcfg.replace(**kw) if kw else tcfg


def elastic_restore(path: str, step: int, like):
    """Checkpoint restore across an elastic CONFIG change, by leaf path.

    ``checkpoint.restore`` is strict: the checkpoint and ``like`` must
    flatten to the same leaves.  An elastic resume breaks that whenever
    the survivor config changes the state LAYOUT, not just its sharding
    — e.g. dropping the int8 wire at a non-pow2 p' removes the per-bucket
    error-feedback buffers (``state["ef"]``) from the train state.  This
    restore matches leaves by the manifest's tree paths instead:

      * a leaf present in both is restored (global shapes must agree),
      * a checkpoint-only leaf is DROPPED (stale state for machinery the
        survivor config no longer runs),
      * a ``like``-only leaf keeps its freshly initialized value (state
        for machinery the old config didn't have).

    Returns ``(tree, info)`` where ``info`` lists the ``dropped`` and
    ``kept_init`` paths so the resume log can show exactly what crossed
    the config boundary.  With identical layouts this is byte-equivalent
    to the strict restore.
    """
    import json
    import os

    import numpy as np

    import jax
    from repro.train import checkpoint as ckpt

    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    like_paths = ckpt._leaf_paths(like)
    ckpt_paths = manifest.get("paths") or []
    if not ckpt_paths or not like_paths:   # no path labels: strict only
        return ckpt.restore(path, step, like), {"dropped": [],
                                                "kept_init": []}
    data = np.load(os.path.join(d, "arrays.npz"))
    by_path = {p: i for i, p in enumerate(ckpt_paths)}
    flat_like, treedef = jax.tree.flatten(like)
    flat, kept_init = [], []
    for lp, lk in zip(like_paths, flat_like):
        i = by_path.pop(lp, None)
        if i is None:
            flat.append(lk)
            kept_init.append(lp)
            continue
        arr = ckpt.load_leaf(data, i, manifest)
        assert tuple(arr.shape) == tuple(np.shape(lk)), (
            f"{lp}: ckpt {arr.shape} vs expected {np.shape(lk)}")
        flat.append(arr.astype(lk.dtype if hasattr(lk, "dtype")
                               else arr.dtype))
    return jax.tree.unflatten(treedef, flat), {
        "dropped": sorted(by_path), "kept_init": kept_init}
