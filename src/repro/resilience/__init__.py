"""Elastic fault-tolerant runtime: deterministic fault injection,
survivor-set rescheduling, and a self-healing serve fleet.

Three layers, one fault model (see README "Fault tolerance"):

  * :mod:`repro.resilience.chaos` — every fault is a scheduled
    :class:`~repro.resilience.chaos.FaultEvent` (replica crash mid-tick,
    straggler tick, global-link slowdown, train-rank loss, store-file
    corruption), either written out explicitly or generated from a seed,
    so every chaos run is exactly reproducible.
  * :mod:`repro.resilience.elastic` — on rank loss, rebuild the
    collective schedules at p' = p - k through the schedule IR's
    non-pow2 adapters, re-derive the tier stack over the degraded group
    occupancy, repartition the ZeRO bucket rows over the survivors, and
    resume from the last checkpoint bit-identically to a fresh p'-rank
    run.
  * :mod:`repro.resilience.supervisor` — a self-healing layer over the
    serve fleet: per-tick heartbeats, crash detection that converts an
    unplanned replica exception into stop -> respawn with in-flight
    requests replayed from prompt + generated prefix (token streams stay
    byte-identical to the fault-free run), and deadline-based admission
    backpressure (shed, or re-queue with deterministic jittered backoff).
"""

from repro.resilience.chaos import (CHAOS_KINDS, ChaosSchedule, FaultEvent,
                                    corrupt_file, degraded_topology,
                                    generate_events, parse_event,
                                    rank_loss_schedule)
from repro.resilience.elastic import (SurvivorPlan, elastic_backend,
                                      elastic_restore, elastic_train_config,
                                      plan_survivors, replan_buckets,
                                      survivor_set)
from repro.resilience.supervisor import (FleetSupervisor, ReplicaCrash,
                                         SupervisorConfig)

__all__ = [
    "CHAOS_KINDS", "ChaosSchedule", "FaultEvent", "corrupt_file",
    "degraded_topology", "generate_events", "parse_event",
    "rank_loss_schedule",
    "SurvivorPlan", "elastic_backend", "elastic_restore",
    "elastic_train_config", "plan_survivors", "replan_buckets",
    "survivor_set",
    "FleetSupervisor", "ReplicaCrash", "SupervisorConfig",
]
