"""The resilience suite's *replay-consistent* deterministic fake engine.

``tests/fleet``'s FakeFns returns insert logits ``onehot(length)`` —
fine for drain/respawn (un-admitted requests replay with the original
prompt) but wrong for CRASH replay, where ``eject_all`` folds the
generated prefix into the prompt: the replay insert then sees length
``L + g`` and would emit ``L + g`` where the fault-free run emitted
``L + g - 1``.  The real engine computes insert logits at the LAST
prompt position (``length - 1``) — exactly the property that makes
crash replay byte-identical — so this fake mirrors it.  Closed-form
greedy stream for prompt length ``L``::

    (L - 1), L, L + 1, ...   (mod V)

with or without crashes mid-stream.
"""

import numpy as np

V = 32


class ReplayFakeFns:
    """Deterministic fake engine whose insert logits sit at the last
    prompt position (``length - 1``), matching the real engine's replay
    semantics across ``crash()``/``eject_all`` prompt folding."""

    def __init__(self, n_slots):
        self.n_slots = n_slots
        self.shardings = {"plan": {}}
        self.trace_counts = {}
        self.insert = self._insert
        self.decode_slots = self._decode
        self.evict = self._evict

    def init_pool(self):
        return {"pos": np.zeros(self.n_slots, np.int64)}

    @staticmethod
    def _onehot(idx):
        out = np.zeros((len(idx), V), np.float32)
        out[np.arange(len(idx)), np.asarray(idx) % V] = 1.0
        return out

    def _insert(self, params, pool, tokens, length, slot):
        pool["pos"][slot] = int(length)
        return self._onehot([int(length) - 1]), pool

    def _decode(self, params, pool, tokens, active):
        logits = self._onehot(pool["pos"])
        pool["pos"] += np.asarray(active, np.int64)
        return logits, pool

    def _evict(self, pool, slot):
        pool["pos"][slot] = 0
        return pool


class FakeTimer:
    """Deterministic perf_counter stand-in: each call advances by
    ``step_s`` so every scheduler step 'measures' a fixed latency."""

    def __init__(self, step_s=1e-3):
        self.step_s = step_s
        self.t = 0.0

    def __call__(self):
        self.t += self.step_s
        return self.t


def expected_stream(L, n):
    """The replay-consistent fake engine's greedy stream for prompt
    length L (the closed form every crash-free AND crashed run must
    reproduce)."""
    return [(L - 1 + i) % V for i in range(n)]
