"""Deterministic, seed-driven fault injection.

Every fault the resilience layer can inject is a scheduled
:class:`FaultEvent` — ``(tick, kind, target, magnitude)`` — so a chaos
run is a pure function of its event list (and the event list itself is a
pure function of ``--chaos-seed`` when generated): two runs with the
same schedule inject byte-identical faults at the same virtual ticks.
Nothing here reads a wall clock or an unseeded RNG.

Kinds
  crash          : the target replica's next tick raises mid-tick (the
                   supervisor's unplanned-exception path, not a drain)
  straggler      : the target replica's next measured tick latency is
                   scaled by ``magnitude`` (poisons the router EWMA the
                   way a slow host would; token streams must not change)
  link_slow      : the topology cost model's global links degrade by
                   ``magnitude`` (``degraded_topology`` scales beta —
                   re-pricing, not re-execution: the decision tables see
                   a slower global tier)
  rank_loss      : ``magnitude`` DP ranks die at train step ``tick``
                   (bridged to ``train.runtime.FailureInjector`` /
                   ``resilience.elastic``)
  corrupt_store  : a measurement/feedback JSON store file is overwritten
                   with seed-derived garbage (exercises the quarantine
                   paths in ``tuner.store`` / ``fleet.feedback``)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

CHAOS_KINDS = ("crash", "straggler", "link_slow", "rank_loss",
               "corrupt_store")

#: kinds the fleet supervisor applies per tick (the serve-side subset)
FLEET_KINDS = ("crash", "straggler")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``tick`` is the fleet tick (serve-side
    kinds) or the train step (``rank_loss``); ``target`` is the replica
    id / first lost rank; ``magnitude`` is the kind's scale factor
    (straggler latency multiple, link beta multiple, ranks lost)."""
    tick: int
    kind: str
    target: int = 0
    magnitude: float = 1.0

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {CHAOS_KINDS}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.tick}")
        if self.magnitude <= 0:
            raise ValueError(
                f"fault magnitude must be > 0, got {self.magnitude}")

    def spec(self) -> str:
        """The CLI spec string this event round-trips through."""
        return f"{self.tick}:{self.kind}:{self.target}:{self.magnitude:g}"


def parse_event(spec: str) -> FaultEvent:
    """Parse a ``TICK:KIND:TARGET[:MAGNITUDE]`` CLI spec
    (``launch/fleet.py --chaos-events``)."""
    parts = spec.split(":")
    if len(parts) not in (3, 4):
        raise ValueError(
            f"chaos event spec {spec!r} is not TICK:KIND:TARGET[:MAGNITUDE]")
    mag = float(parts[3]) if len(parts) == 4 else 1.0
    return FaultEvent(int(parts[0]), parts[1], int(parts[2]), mag)


def generate_events(seed: int, n_ticks: int, n_replicas: int,
                    n_events: int = 2,
                    kinds: Sequence[str] = FLEET_KINDS,
                    straggler_scale: float = 4.0) -> Tuple[FaultEvent, ...]:
    """Seed-driven event list: ``n_events`` faults drawn uniformly over
    ticks ``[1, n_ticks)`` x replicas x ``kinds``.  Same seed, same
    arguments -> the identical schedule, every time."""
    for k in kinds:
        if k not in CHAOS_KINDS:
            raise ValueError(f"unknown fault kind {k!r}")
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n_events):
        kind = kinds[int(rng.randint(len(kinds)))]
        out.append(FaultEvent(
            tick=int(rng.randint(1, max(2, n_ticks))),
            kind=kind,
            target=int(rng.randint(n_replicas)),
            magnitude=straggler_scale if kind == "straggler" else 1.0))
    return tuple(sorted(out, key=lambda e: (e.tick, e.kind, e.target)))


class ChaosSchedule:
    """An immutable, tick-indexed view over a fault-event list."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.tick, e.kind, e.target)))
        self._by_tick: Dict[int, List[FaultEvent]] = {}
        for ev in self.events:
            self._by_tick.setdefault(ev.tick, []).append(ev)

    def at(self, tick: int) -> Tuple[FaultEvent, ...]:
        return tuple(self._by_tick.get(tick, ()))

    def of_kind(self, *kinds: str) -> Tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind in kinds)

    @property
    def last_tick(self) -> int:
        return self.events[-1].tick if self.events else -1

    def signature(self) -> str:
        """Human/log-friendly one-liner; also the reproduction recipe."""
        return " ".join(e.spec() for e in self.events) or "(none)"


# ---------------------------------------------------------------------------
# Appliers
# ---------------------------------------------------------------------------

def degraded_topology(topo, beta_scale: float, alpha_scale: float = 1.0):
    """The cost model's view of a global-link slowdown (the ``link_slow``
    fault kind): a new frozen topo with the slow tier ``beta_scale``x
    slower.  Delegates to :func:`repro.topology.cost.degrade_topology` —
    the cost model and decision tables are pure in the topo argument, so
    re-pricing a degraded network is just passing the result in
    (``cost.predict_time``, ``table.build_table``)."""
    from repro.topology.cost import degrade_topology
    return degrade_topology(topo, beta_scale, alpha_scale=alpha_scale)


def corrupt_file(path: str, seed: int = 0, nbytes: int = 64) -> str:
    """Overwrite ``path`` with seed-derived garbage (same seed, same
    garbage).  The write is deliberately NOT atomic — a torn write is
    exactly the failure the store quarantine paths must absorb."""
    rng = np.random.RandomState(seed)
    garbage = bytes(bytearray(rng.randint(0, 256, size=nbytes, dtype=np.uint8)))
    with open(path, "wb") as f:
        f.write(b"{corrupt" + garbage)
    return path


def rank_loss_schedule(events: Sequence[FaultEvent]) -> Dict[int, bool]:
    """Bridge ``rank_loss`` events to ``train.runtime.FailureInjector``'s
    ``{step: permanent}`` schedule (rank loss is always permanent — the
    transient-restart path keeps the same rank count)."""
    return {e.tick: True for e in events if e.kind == "rank_loss"}


def lost_ranks(events: Sequence[FaultEvent], step: int) -> Tuple[int, ...]:
    """The ranks a ``rank_loss`` event at ``step`` removes:
    ``magnitude`` consecutive ranks starting at ``target``."""
    for e in events:
        if e.kind == "rank_loss" and e.tick == step:
            k = int(e.magnitude)
            return tuple(range(e.target, e.target + k))
    return ()
