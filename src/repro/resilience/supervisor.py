"""Self-healing fleet supervision: heartbeats, crash -> respawn, backpressure.

The :class:`FleetSupervisor` owns a :class:`repro.fleet.fleet.Fleet`'s
run loop and layers three behaviors over it, none of which change any
request's token stream (the fleet-equivalence property extends through
crashes — tests/resilience/test_chaos_equivalence.py):

  * **Chaos arming** — scheduled :class:`~repro.resilience.chaos.FaultEvent`
    faults are applied at their tick: ``crash`` arms
    :meth:`Replica.inject_fault` (the exception surfaces through the real
    tick path, mid-tick), ``straggler`` scales the next measured tick
    latency (poisons the router EWMA the way a slow host would).
  * **Crash recovery** — an unplanned replica exception (injected or
    genuine) is caught by the fleet's ``fault_handler`` hook, converted
    into :meth:`Replica.crash` (waiting + in-flight requests ejected,
    in-flight ones with their generated prefix folded into the prompt so
    replay re-derives byte-identical continuations), the displaced
    requests resubmitted through the router, and a respawn scheduled
    ``respawn_delay`` ticks out.  Time-to-recovery per crash is recorded
    (the MTTR the chaos benchmark gates on).
  * **Admission backpressure** — un-routed requests that have waited
    longer than ``deadline_ticks`` are shed (finished with reason
    ``"shed"``) or re-queued with a deterministic seed-jittered backoff,
    so an overloaded or crash-thinned fleet degrades by policy instead of
    by unbounded queue growth.

Everything is driven by the fleet's integer virtual clock and seeded
RNGs: same trace + same chaos schedule -> the identical run.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.fleet.fleet import Fleet, FleetEvent
from repro.obs import metrics as obs_metrics
from repro.obs import timeline as obs_timeline
from repro.resilience.chaos import ChaosSchedule


class ReplicaCrash(RuntimeError):
    """The injected unplanned-replica-failure exception.  Genuine engine
    exceptions take the same recovery path; this type exists so chaos
    runs are distinguishable from real faults in logs."""


@dataclass(frozen=True)
class SupervisorConfig:
    #: ticks from crash to respawn (the fleet readmits the replica then)
    respawn_delay: int = 1
    #: un-routed requests older than this many ticks hit backpressure;
    #: None disables the deadline entirely
    deadline_ticks: Optional[int] = None
    #: what backpressure does: "requeue" (deterministic jittered backoff)
    #: or "shed" (finish the request unserved with reason "shed")
    backpressure: str = "requeue"
    #: requeue backoff: new arrival = now + base + U{0..jitter} (seeded)
    backoff_base: int = 1
    backoff_jitter: int = 2
    #: seed for the backoff jitter draw (per-supervisor RandomState)
    seed: int = 0
    #: hard tick budget for :meth:`FleetSupervisor.run`; None = no guard
    max_ticks: Optional[int] = None

    def __post_init__(self):
        if self.backpressure not in ("requeue", "shed"):
            raise ValueError(
                f"backpressure must be 'requeue' or 'shed', got "
                f"{self.backpressure!r}")
        if self.respawn_delay < 1:
            raise ValueError("respawn_delay must be >= 1 (a crashed "
                             "replica cannot respawn within its own tick)")


@dataclass
class CrashRecord:
    """One crash -> recovery cycle (the MTTR ledger entry)."""
    replica: int
    crash_tick: int
    displaced: int
    respawn_tick: Optional[int] = None

    @property
    def ttr(self) -> Optional[int]:
        """Ticks from crash to the replica rejoining the healthy set."""
        if self.respawn_tick is None:
            return None
        return self.respawn_tick - self.crash_tick


@dataclass
class HealthProbe:
    """One per-tick heartbeat row for one replica."""
    tick: int
    replica: int
    state: str
    load: int
    crashes: int


class FleetSupervisor:
    """Drives a fleet to drain under a chaos schedule, healing as it goes.

    The supervisor owns the loop (it cannot ride :meth:`Fleet.run`, whose
    stall heuristic only knows the static event list — respawns here are
    scheduled dynamically in response to crashes).  Per tick it arms due
    faults, fires due respawns, applies deadline backpressure, steps the
    fleet once, and records a heartbeat for every replica.
    """

    def __init__(self, fleet: Fleet, chaos: ChaosSchedule = ChaosSchedule(),
                 cfg: SupervisorConfig = SupervisorConfig()):
        self.fleet = fleet
        self.chaos = chaos
        self.cfg = cfg
        self._rng = np.random.RandomState(cfg.seed)
        #: replica id -> tick at which to respawn it
        self._respawn_at: Dict[int, int] = {}
        self.crash_log: List[CrashRecord] = []
        self.heartbeats: List[HealthProbe] = []
        self.shed_rids: List[int] = []
        self.n_requeued = 0
        fleet.fault_handler = self._on_fault

    # -- crash recovery ------------------------------------------------------

    def _on_fault(self, rep, exc: BaseException) -> None:
        """The fleet's ``fault_handler``: unplanned exception -> crash,
        replay-resubmit the displaced requests, schedule the respawn."""
        now = self.fleet.clock
        displaced = rep.crash()
        self.crash_log.append(CrashRecord(
            replica=rep.rid, crash_tick=now, displaced=len(displaced)))
        if obs_metrics.enabled():
            obs_metrics.get_registry().inc(
                "fleet_crashes", 1.0, replica=rep.rid)
            obs_timeline.get_timeline().instant(
                "replica_crash", "fleet", float(now), track=str(rep.rid),
                replica=rep.rid, displaced=len(displaced))
        for req in displaced:
            # in-flight prefixes were folded into the prompt by eject_all;
            # re-routing is plain resubmission (arrival is in the past, so
            # the request is delivered on the next tick's arrival pass)
            self.fleet.submit(req)
        self._respawn_at[rep.rid] = now + self.cfg.respawn_delay

    def _fire_respawns(self) -> None:
        now = self.fleet.clock
        due = [rid for rid, t in self._respawn_at.items() if t <= now]
        for rid in sorted(due):
            rep = self.fleet.replicas[rid]
            rep.respawn()
            if obs_metrics.enabled():
                obs_metrics.get_registry().inc("fleet_respawns", 1.0,
                                               replica=rid)
                obs_timeline.get_timeline().instant(
                    "replica_respawn", "fleet", float(now), track=str(rid))
            # a fresh incarnation's latency is not the dead one's: drop
            # the EWMA so the router re-learns instead of trusting a
            # possibly straggler-poisoned estimate
            self.fleet.router.reset(rid)
            del self._respawn_at[rid]
            for rec in reversed(self.crash_log):
                if rec.replica == rid and rec.respawn_tick is None:
                    rec.respawn_tick = now
                    break

    # -- chaos arming --------------------------------------------------------

    def _arm_chaos(self) -> None:
        for ev in self.chaos.at(self.fleet.clock):
            if obs_metrics.enabled():
                obs_metrics.get_registry().inc(
                    "chaos_events", 1.0, kind=ev.kind, target=ev.target)
                obs_timeline.get_timeline().instant(
                    f"chaos_{ev.kind}", "chaos", float(ev.tick),
                    track=str(ev.target), kind=ev.kind, target=ev.target,
                    magnitude=ev.magnitude)
            if ev.kind == "crash":
                self.fleet.replicas[ev.target].inject_fault(ReplicaCrash(
                    f"chaos: injected crash of replica {ev.target} at "
                    f"tick {ev.tick}"))
            elif ev.kind == "straggler":
                self.fleet.replicas[ev.target].latency_scale = ev.magnitude
            # link_slow / rank_loss / corrupt_store are not per-tick fleet
            # faults: they are applied by the launcher / train runtime
            # before or outside the serve loop (see resilience.chaos)

    # -- backpressure --------------------------------------------------------

    def _backpressure(self) -> None:
        if self.cfg.deadline_ticks is None:
            return
        now = self.fleet.clock
        keep = []
        for arrival, rid, req in self.fleet._pending:
            if now - arrival <= self.cfg.deadline_ticks:
                keep.append((arrival, rid, req))
            elif self.cfg.backpressure == "shed":
                req.finished = True
                req.finish_reason = "shed"
                req.finished_at = float(now)
                self.shed_rids.append(req.rid)
                if obs_metrics.enabled():
                    obs_metrics.get_registry().inc("fleet_shed")
            else:
                jitter = int(self._rng.randint(self.cfg.backoff_jitter + 1))
                req.arrival = float(now + self.cfg.backoff_base + jitter)
                keep.append((req.arrival, rid, req))
                self.n_requeued += 1
                if obs_metrics.enabled():
                    obs_metrics.get_registry().inc("fleet_requeued")
        keep.sort()
        self.fleet._pending[:] = keep

    # -- the loop ------------------------------------------------------------

    def _heartbeat(self) -> None:
        tick = self.fleet.clock
        for rep in self.fleet.replicas:
            self.heartbeats.append(HealthProbe(
                tick=tick, replica=rep.rid, state=rep.state, load=rep.load,
                crashes=rep.n_crashes))

    def step(self, events: Sequence[FleetEvent] = ()) -> bool:
        """One supervised tick; returns False when fully drained."""
        self._fire_respawns()
        self._arm_chaos()
        self._backpressure()
        self._heartbeat()
        return self.fleet.step(events)

    def _stalled(self) -> bool:
        """Pending work, nothing ACTIVE, and no respawn scheduled —
        the dynamic-recovery analogue of :meth:`Fleet._stalled`."""
        return (bool(self.fleet._pending) and not self.fleet._healthy()
                and not self._respawn_at)

    def run(self, events: Sequence[FleetEvent] = ()) -> dict:
        """Drain the fleet under the chaos schedule; returns
        :meth:`report` (fleet stats + resilience accounting)."""
        events = tuple(events)
        while self.step(events):
            if (self.cfg.max_ticks is not None
                    and self.fleet.clock > self.cfg.max_ticks):
                raise RuntimeError(
                    f"supervised fleet exceeded max_ticks="
                    f"{self.cfg.max_ticks} (pending="
                    f"{len(self.fleet._pending)}, crashes="
                    f"{len(self.crash_log)})")
            if self._stalled():
                raise RuntimeError(
                    f"supervised fleet stalled at tick {self.fleet.clock}: "
                    f"pending requests, no ACTIVE replica, no scheduled "
                    f"respawn")
        # pending-but-unfired respawns after drain still heal the fleet
        while self._respawn_at:
            self.fleet.clock += 1
            self._fire_respawns()
        return self.report()

    # -- accounting ----------------------------------------------------------

    def mttr(self) -> Optional[float]:
        """Mean ticks-to-recovery over recovered crashes (None if no
        crash happened)."""
        ttrs = [rec.ttr for rec in self.crash_log if rec.ttr is not None]
        if not ttrs:
            return None
        return float(np.mean(ttrs))

    def report(self) -> dict:
        stats = self.fleet.stats()
        if obs_metrics.enabled():
            mttr = self.mttr()
            if mttr is not None:
                obs_metrics.get_registry().set_gauge("fleet_mttr_ticks",
                                                     mttr)
        stats["resilience"] = {
            "chaos_signature": self.chaos.signature(),
            "crashes": [
                {"replica": rec.replica, "crash_tick": rec.crash_tick,
                 "displaced": rec.displaced,
                 "respawn_tick": rec.respawn_tick, "ttr": rec.ttr}
                for rec in self.crash_log
            ],
            "mttr_ticks": self.mttr(),
            "shed": sorted(self.shed_rids),
            "requeued": self.n_requeued,
            "heartbeat_rows": len(self.heartbeats),
            "final_health": {
                rep.rid: {"state": rep.state, "crashes": rep.n_crashes,
                          "respawns": rep.n_respawns}
                for rep in self.fleet.replicas
            },
        }
        return stats
