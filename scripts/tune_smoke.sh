#!/usr/bin/env bash
# tune-smoke: end-to-end CPU run of the empirical autotuner.
#
# Runs `launch/tune.py --grid tiny` on forced host devices (pallas cells
# in interpret mode), then asserts:
#   * the measured table round-trips through topology/table.py and
#     carries measured cells;
#   * every packaged analytic table carries the joint (backend, wire)
#     rows of format 3 and reads as all-analytic.
#
# Usage: scripts/tune_smoke.sh [out-dir]   (default ./tune-smoke)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"
OUT="${1:-./tune-smoke}"
export REPRO_MEASURE_DIR="$OUT/measurements"
export REPRO_MEASURED_TABLE_DIR="$OUT/tables"

python -m repro.launch.tune --grid tiny --topology tpu_multipod --devices 4

python - <<'EOF'
import glob, json, os
from repro.topology import table as tbl

# the measured table exists, round-trips, and carries measured cells
path = tbl.measured_table_path("tpu_multipod")
t = tbl.DecisionTable.load(path)
n = t.measured_cell_count()
assert n > 0, "tune run produced no measured cells"
rt_path = path + ".roundtrip"
t.save(rt_path)
assert tbl.DecisionTable.load(rt_path) == t, "measured table round-trip"

# tuning="measured" dispatch actually reads it
os.environ.pop("REPRO_TABLE_DIR", None)
merged = tbl.load_table("tpu_multipod", tuning="measured")
assert merged.measured_cell_count() == n

# every packaged analytic table is current-format with wire rows and
# reads as all-analytic (old formats 1/2 parse too -- tests/tuner)
packaged = glob.glob(os.path.join(tbl._PACKAGED_DIR, "*.json"))
assert packaged, "no packaged tables found"
for f in packaged:
    with open(f) as fh:
        d = json.load(fh)
    assert d["format"] == 3 and d["wire_entries"], f
    tab = tbl.DecisionTable.load(f)
    assert not tab.provenance  # reads as all-analytic
    assert tab.provenance_of("allreduce", 8, 1 << 20) == "analytic"
    b, w = tab.lookup_wire("reduce_scatter", 8, 1 << 26)
    assert w in ("float32", "bfloat16", "int8"), (f, b, w)
print(f"tune-smoke OK: {n} measured cells; "
      f"{len(packaged)} packaged tables parse")
EOF
