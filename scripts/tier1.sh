#!/usr/bin/env bash
# Tier-1 smoke: the exact verify command CI and ROADMAP.md use.
# Works from any cwd; extra args are forwarded to pytest
# (e.g. scripts/tier1.sh tests/topology -k auto).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
